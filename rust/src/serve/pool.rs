//! Sharded engine pool: N decode workers over ONE set of packed codes.
//!
//! The PEQA memory model makes a serving pool almost free to replicate:
//! the packed sub-4-bit codes of the base model are immutable and shared
//! (an [`Arc`] inside every `PackedMatrix` — cloning a
//! [`PackedModel`](crate::model::PackedModel) copies pointers, not
//! gigabytes), so per-worker state is only what *must* be private — the
//! f32 scale/zero tensors of the applied task adapter, the KV caches,
//! and the scratch arena. N engines cost one model plus N kilobyte-scale
//! adapter slots.
//!
//! Architecture:
//!
//! ```text
//!   clients ──▶ PoolHandle::submit / submit_stream
//!                  │  (typed admission: Overloaded past queue_cap)
//!                  ▼
//!             Dispatcher            per-task bounded FIFO queues
//!                  │  next_batch()  task-affine pick, deadline shed
//!        ┌─────────┼─────────┐
//!        ▼         ▼         ▼
//!     worker 0  worker 1  worker N-1     one Scheduler each
//!     (engine)  (engine)  (engine)       (scales/zeros + KV + arena)
//!        └─────────┴─────────┘
//!              Arc<packed codes>         shared, never copied
//! ```
//!
//! Each worker wraps the single-threaded [`Scheduler`] — the pool reuses
//! its continuous batching, cross-request prefill, stop handling and
//! cache recycling verbatim, which is also why pooled generations are
//! bitwise identical to the single-engine path under greedy decoding:
//! per-sequence math is batch-composition independent, and the
//! dispatcher only changes *which worker* runs a request, never what
//! that worker computes. Task-affine handout
//! ([`Dispatcher::next_batch`]) keeps a worker on its applied adapter
//! while that task has queued work, so concurrent multi-task traffic
//! converges to roughly one task per worker and scale swaps mostly
//! vanish ([`ServeMetrics::swaps_avoided`] counts the dodged ones).
//!
//! Streaming: [`PoolHandle::submit_stream`] returns a bounded
//! [`StreamEvent`] channel fed at every token acceptance inside the
//! decode loop, terminated by exactly one `Done` (whose `tokens` equal
//! the concatenated `Token` events bitwise) or `Error`. The channel is
//! bounded ([`STREAM_CHANNEL_CAP`]): a client that stops draining
//! eventually blocks the worker decoding its batch — backpressure ends
//! at the producer, queue growth is impossible by construction.
//!
//! Paged KV: with [`PoolConfig::kv_pages`] > 0 each worker's scheduler
//! serves sequences out of its own [`PagePool`](super::kvpage::PagePool)
//! (KV rows are engine-private, so pools are disjoint and merge-safe:
//! `kv_pages_peak` maxes, `kv_pages_shared` sums), and the dispatcher
//! rejects requests that could never fit the per-worker budget with
//! [`ServeError::KvExhausted`] before they reach a queue.
//!
//! Hot reload: [`EnginePool::spawn_watching`] shares one registry watch
//! across workers. Between bursts a due worker (interval elapsed,
//! try-lock — pollers never queue behind each other) checks the
//! manifest generation; a newly published generation is strict-validated
//! by reloading the polling worker first, then adopted lock-free by the
//! rest via a version counter. A bad generation is warned about once
//! and the live one keeps serving everywhere.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::dispatch::{DispatchConfig, Dispatcher, PoolRequest};
use super::engine::{Engine, ModelGeom, Sampling};
use super::scheduler::{Scheduler, SchedulerConfig};
use super::types::{AdapterStore, GenResponse, ServeError, ServeMetrics, StreamEvent};
use crate::model::PackedModel;
use crate::store::Registry;
use crate::util::sync::{lock_clean, try_lock_clean};

/// Capacity of each streaming reply channel: enough slack that a client
/// draining at generation speed never stalls the worker, small enough
/// that an abandoned-but-undropped receiver backpressures instead of
/// buffering a whole generation.
pub const STREAM_CHANNEL_CAP: usize = 32;

/// Engine-pool knobs: scheduler config × admission control × pool shape.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Number of engine workers (threads). Each owns a full
    /// [`Scheduler`]; all share one set of packed codes.
    pub engines: usize,
    /// Per-worker continuous-batching width ([`SchedulerConfig::max_batch`])
    /// — also the dispatcher handout size.
    pub max_batch: usize,
    /// Per-sequence KV window ([`SchedulerConfig::window`]).
    pub window: usize,
    pub sampling: Sampling,
    /// Sampling seed; worker i uses `seed + i` so top-k streams
    /// decorrelate (greedy ignores it).
    pub seed: u64,
    pub strict_coverage: bool,
    /// Per-task ingress bound ([`DispatchConfig::queue_cap`]); 0 = unbounded.
    pub queue_cap: usize,
    /// Queue deadline ([`DispatchConfig::deadline_ms`]); 0 = no shedding.
    pub deadline_ms: u64,
    /// Task-affinity burst ([`DispatchConfig::affinity_burst`]).
    pub affinity_burst: usize,
    /// Per-worker paged-KV pool size ([`SchedulerConfig::kv_pages`]);
    /// 0 keeps the per-sequence ring buffers. Each worker owns its own
    /// page pool (KV rows are engine-private), so the pool-wide budget
    /// is `engines × kv_pages` pages.
    pub kv_pages: usize,
    /// Tokens per KV page ([`SchedulerConfig::page_tokens`]).
    pub page_tokens: usize,
    /// Minimum ms between registry hot-reload polls (spawn_watching
    /// only). 0 = check before every burst.
    pub watch_interval_ms: u64,
    /// Fault injection for the poison-recovery tests: a worker handed a
    /// batch of this task panics while holding the metrics lock. Only
    /// exists in test builds, so release pools cannot even express it.
    #[cfg(test)]
    pub panic_on_task: Option<&'static str>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        let s = SchedulerConfig::default();
        let d = DispatchConfig::default();
        PoolConfig {
            engines: 2,
            max_batch: s.max_batch,
            window: s.window,
            sampling: s.sampling,
            seed: s.seed,
            strict_coverage: s.strict_coverage,
            queue_cap: d.queue_cap,
            deadline_ms: d.deadline_ms,
            affinity_burst: d.affinity_burst,
            kv_pages: s.kv_pages,
            page_tokens: s.page_tokens,
            watch_interval_ms: 0,
            #[cfg(test)]
            panic_on_task: None,
        }
    }
}

/// Shared registry-watch state (spawn_watching pools only): one poller
/// at a time (try-lock), adopted by every worker through `version`.
struct PoolWatch {
    /// Bumped once per successfully validated + published store; workers
    /// compare against their adopted version without taking the lock.
    version: AtomicU64,
    inner: Mutex<WatchInner>,
    interval_ms: u64,
}

struct WatchInner {
    registry: Registry,
    last_poll: Instant,
    /// Last generation a load was attempted for — a rejected generation
    /// is warned about once, not once per worker per burst.
    last_attempted: u64,
    /// Generation currently serving.
    live: u64,
    /// Latest validated adapter store; workers clone it on adoption
    /// (kilobytes per task — the whole point of the paper).
    latest: Option<AdapterStore>,
}

/// Cheaply cloneable client handle to a running [`EnginePool`].
#[derive(Clone)]
pub struct PoolHandle {
    dispatcher: Arc<Dispatcher>,
    metrics: Arc<Mutex<ServeMetrics>>,
}

impl PoolHandle {
    /// Blocking generate: admission-checked at submit ([`ServeError::Overloaded`]
    /// past the task's queue cap), then waits for the terminal event.
    pub fn submit(
        &self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
    ) -> Result<GenResponse, ServeError> {
        let (tx, rx) = sync_channel(1);
        self.dispatcher.submit(task, prompt, max_new, stop, tx, false)?;
        match rx.recv() {
            Ok(StreamEvent::Done(resp)) => Ok(resp),
            Ok(StreamEvent::Error(e)) => Err(e),
            Ok(StreamEvent::Token(_)) => {
                Err(ServeError::Failed("token event on a non-streaming reply".into()))
            }
            Err(_) => Err(ServeError::Failed("pool dropped the request".into())),
        }
    }

    /// Streaming generate: returns immediately (after admission) with a
    /// bounded channel of [`StreamEvent`]s — `Token` per accepted token,
    /// then one `Done`/`Error`. Drain with
    /// [`collect_stream`](super::types::collect_stream) to reassemble;
    /// the tokens are bitwise what [`Self::submit`] would return.
    pub fn submit_stream(
        &self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
    ) -> Result<Receiver<StreamEvent>, ServeError> {
        let (tx, rx) = sync_channel(STREAM_CHANNEL_CAP);
        self.dispatcher.submit(task, prompt, max_new, stop, tx, true)?;
        Ok(rx)
    }

    /// Pool-wide metrics snapshot: per-worker scheduler metrics (merged
    /// after every drained burst) plus the dispatcher's admission
    /// counters (queue depth high-water, shed count, swaps avoided).
    pub fn metrics(&self) -> ServeMetrics {
        // lock_clean: a worker that panicked mid-merge poisons this
        // mutex; the snapshot must still be readable afterwards.
        let mut m = lock_clean(&self.metrics).clone();
        m.merge(&self.dispatcher.admission_metrics());
        m
    }

    /// Queued (not yet dispatched) requests.
    pub fn pending(&self) -> usize {
        self.dispatcher.pending()
    }
}

/// Owning handle: N worker threads, shared dispatcher, shared metrics.
/// Dropping (or [`EnginePool::shutdown`]) closes admission, drains the
/// queues, and joins every worker.
pub struct EnginePool {
    handle: PoolHandle,
    joins: Vec<JoinHandle<()>>,
}

impl EnginePool {
    /// Spawn `cfg.engines` workers over clones of `model` (packed codes
    /// shared, scales/zeros per worker) and `adapters`.
    pub fn spawn(
        model: PackedModel,
        geom: ModelGeom,
        threads: usize,
        adapters: AdapterStore,
        cfg: PoolConfig,
    ) -> Result<EnginePool> {
        Self::spawn_inner(model, geom, threads, adapters, cfg, None)
    }

    /// [`Self::spawn`] plus adapter hot-reload from a [`Registry`]: the
    /// registry's current generation is the already-live baseline; later
    /// publishes are picked up between bursts (poll cadence gated by
    /// [`PoolConfig::watch_interval_ms`]) and adopted by every worker.
    pub fn spawn_watching(
        model: PackedModel,
        geom: ModelGeom,
        threads: usize,
        adapters: AdapterStore,
        cfg: PoolConfig,
        registry: Registry,
    ) -> Result<EnginePool> {
        let gen = registry.generation().map_err(|e| {
            anyhow!("registry {} is unreadable: {e:#}", registry.dir().display())
        })?;
        let watch = PoolWatch {
            version: AtomicU64::new(0),
            inner: Mutex::new(WatchInner {
                registry,
                // peqa-lint: allow(nondeterminism-sources) -- poll pacing
                // only: gates how often workers stat the registry; never
                // influences decoded tokens.
                last_poll: Instant::now(),
                last_attempted: gen,
                live: gen,
                latest: None,
            }),
            interval_ms: cfg.watch_interval_ms,
        };
        Self::spawn_inner(model, geom, threads, adapters, cfg, Some(Arc::new(watch)))
    }

    fn spawn_inner(
        model: PackedModel,
        geom: ModelGeom,
        threads: usize,
        adapters: AdapterStore,
        cfg: PoolConfig,
        watch: Option<Arc<PoolWatch>>,
    ) -> Result<EnginePool> {
        let n = cfg.engines.max(1);
        let dispatcher = Arc::new(Dispatcher::new(DispatchConfig {
            queue_cap: cfg.queue_cap,
            deadline_ms: cfg.deadline_ms,
            affinity_burst: cfg.affinity_burst,
            // Ingress feasibility gates: a request that could never fit a
            // worker's window / page pool is rejected typed at submit
            // instead of reaching (and failing on) a worker.
            max_prompt: cfg.window,
            kv_pages: cfg.kv_pages,
            page_tokens: cfg.page_tokens,
        }));
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut joins = Vec::with_capacity(n);
        for i in 0..n {
            // A PackedModel clone shares the Arc'd packed codes; only
            // the f32 scale/zero tensors (and the fp head/norms) are
            // per-worker — the pool's memory cost is adapters × N, not
            // model × N.
            let engine = Engine::from_packed(model.clone(), geom, threads)?;
            let sched_cfg = SchedulerConfig {
                max_batch: cfg.max_batch,
                window: cfg.window,
                sampling: cfg.sampling,
                seed: cfg.seed.wrapping_add(i as u64),
                strict_coverage: cfg.strict_coverage,
                kv_pages: cfg.kv_pages,
                page_tokens: cfg.page_tokens,
            };
            let sched = Scheduler::new(engine, adapters.clone(), sched_cfg)?;
            let d = dispatcher.clone();
            let m = metrics.clone();
            let w = watch.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("peqa-pool-{i}"))
                    .spawn(move || worker_main(sched, d, m, w, cfg))?,
            );
        }
        Ok(EnginePool { handle: PoolHandle { dispatcher, metrics }, joins })
    }

    pub fn handle(&self) -> PoolHandle {
        self.handle.clone()
    }

    /// Close admission, let the workers drain every queued request, join
    /// them, and return the final merged metrics.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.handle.dispatcher.close();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        self.handle.metrics()
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        self.handle.dispatcher.close();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// One pool worker: pull a task-affine batch, feed it through the owned
/// [`Scheduler`], reply per request, merge metrics; between bursts,
/// adopt / poll adapter generations. Exits when the dispatcher is
/// closed and drained.
fn worker_main(
    mut sched: Scheduler,
    dispatcher: Arc<Dispatcher>,
    metrics: Arc<Mutex<ServeMetrics>>,
    watch: Option<Arc<PoolWatch>>,
    cfg: PoolConfig,
) {
    let mut current_task: Option<String> = None;
    let mut affinity_run = 0usize;
    let mut adopted_version = 0u64;
    let mut waiting: Vec<(u64, u64, SyncSender<StreamEvent>)> = Vec::new();
    while let Some((task, batch)) =
        dispatcher.next_batch(current_task.as_deref(), &mut affinity_run, cfg.max_batch)
    {
        // Fault injection (test builds only): die exactly the way a real
        // decode bug would — mid-burst, lock in hand. The batch's reply
        // senders drop with this stack frame, so clients get a typed
        // "pool dropped the request" instead of a hang, and everything
        // else in the pool must shrug the poisoned mutex off.
        #[cfg(test)]
        if cfg.panic_on_task.is_some_and(|t| t == task) {
            let _g = lock_clean(&metrics);
            panic!("deliberate test panic while holding the metrics lock");
        }
        // Between-burst reload point: after the dispatcher handed out
        // work, before any of it is checked against the task set — a
        // generation published a moment ago can serve this very burst.
        if let Some(w) = &watch {
            maybe_reload(&mut sched, w, &mut adopted_version, &mut current_task);
        }
        // (scheduler id, pool id, reply) per admitted request.
        waiting.clear();
        for r in batch {
            let PoolRequest { id, task, prompt, max_new, stop, submitted, reply, stream } = r;
            if !sched.has_task(&task) {
                let _ = reply.send(StreamEvent::Error(ServeError::Failed(format!(
                    "no adapter registered for task '{task}'"
                ))));
                continue;
            }
            let sink = if stream { Some(reply.clone()) } else { None };
            // The dispatcher's ingress gates mirror the scheduler's, so a
            // reject here is a defensive backstop (config drift), not the
            // normal path.
            match sched.submit_queued_at(&task, prompt, max_new, stop, sink, submitted) {
                Ok(sid) => waiting.push((sid, id, reply)),
                Err(e) => {
                    let _ = reply.send(StreamEvent::Error(e));
                }
            }
        }
        if sched.pending() > 0 {
            match sched.run_until_idle() {
                Ok(responses) => {
                    for mut resp in responses {
                        if let Some(pos) = waiting.iter().position(|(sid, _, _)| *sid == resp.id)
                        {
                            let (_, pool_id, reply) = waiting.swap_remove(pos);
                            // Clients know the pool-wide id from submit.
                            resp.id = pool_id;
                            let _ = reply.send(StreamEvent::Done(resp));
                        }
                    }
                    current_task = Some(task);
                }
                Err(e) => {
                    // Fail every request of the burst (streamed ones get
                    // the terminal Error after their partial tokens) and
                    // drop anything still queued behind the failure.
                    sched.clear_queue();
                    let msg = format!("decode failed: {e:#}");
                    for (_, _, reply) in waiting.drain(..) {
                        let _ = reply.send(StreamEvent::Error(ServeError::Failed(msg.clone())));
                    }
                    // Engine adapter state is uncertain mid-error; make
                    // the next pick re-apply instead of assuming.
                    current_task = None;
                }
            }
        }
        let delta = std::mem::take(&mut sched.metrics);
        // lock_clean: merge into whatever state survives a peer's panic
        // — losing one worker's delta is acceptable, cascading is not.
        lock_clean(&metrics).merge(&delta);
    }
}

/// Adopt a newer validated adapter generation (lock-free fast path on
/// the shared version counter), then — if this worker wins the try-lock
/// and the poll interval elapsed — poll the registry for a fresh
/// publish, validating it by reloading this worker's scheduler before
/// sharing it with the rest of the pool.
fn maybe_reload(
    sched: &mut Scheduler,
    w: &PoolWatch,
    adopted_version: &mut u64,
    current_task: &mut Option<String>,
) {
    // Fast path: another worker already validated a newer store.
    let v = w.version.load(Ordering::Acquire);
    if v != *adopted_version {
        let store = lock_clean(&w.inner).latest.clone();
        if let Some(store) = store {
            match sched.reload_adapters(store) {
                Ok(_) => *current_task = None,
                // Validated once already; per-worker failure would mean
                // engines disagree on prefixes — impossible by
                // construction (clones of one model) but never fatal.
                Err(e) => crate::warn!("pool worker adapter adoption failed: {e:#}"),
            }
        }
        *adopted_version = v;
    }
    // Slow path: poll the registry. try-lock — if another worker is
    // polling right now, this one just serves (`None` here means held,
    // not poisoned: try_lock_clean recovers a poisoned-but-free lock).
    let Some(mut inner) = try_lock_clean(&w.inner) else { return };
    if (inner.last_poll.elapsed().as_millis() as u64) < w.interval_ms {
        return;
    }
    // peqa-lint: allow(nondeterminism-sources) -- poll pacing only:
    // wall-clock gates registry stats, never decoded output.
    inner.last_poll = Instant::now();
    let gen = match inner.registry.generation() {
        Ok(g) => g,
        Err(e) => {
            crate::warn!("registry poll failed: {e:#} — still serving generation {}", inner.live);
            return;
        }
    };
    if gen == inner.last_attempted {
        return;
    }
    inner.last_attempted = gen;
    let pairs = match inner.registry.load() {
        Ok((_, pairs)) if pairs.is_empty() => {
            crate::warn!("registry generation {gen} has no published adapters — ignored");
            return;
        }
        Ok((_, pairs)) => pairs,
        Err(e) => {
            crate::warn!(
                "registry load failed: {e:#} — still serving generation {}",
                inner.live
            );
            return;
        }
    };
    let mut store = AdapterStore::new();
    let n_tasks = pairs.len();
    for (task, ck) in pairs {
        store.insert(task, ck);
    }
    // Validate on this worker first; only a generation that actually
    // reloads is published to the pool.
    match sched.reload_adapters(store.clone()) {
        Ok(_) => {
            inner.live = gen;
            inner.latest = Some(store);
            let v = w.version.fetch_add(1, Ordering::AcqRel) + 1;
            *adopted_version = v;
            *current_task = None;
            crate::info!(
                "pool hot-reloaded adapter generation {gen} ({n_tasks} task(s))"
            );
        }
        Err(e) => {
            crate::warn!(
                "adapter generation {gen} rejected: {e:#} — still serving generation {}",
                inner.live
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{synth_adapters, synth_packed};

    fn tiny_parts() -> (PackedModel, ModelGeom, AdapterStore) {
        let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
        let (pm, base_q) = synth_packed(&geom, 4, None, 3).unwrap();
        let adapters = synth_adapters(&base_q, &["a", "b"], 5);
        (pm, geom, adapters)
    }

    #[test]
    fn pool_serves_multiple_tasks_and_merges_metrics() {
        let (pm, geom, adapters) = tiny_parts();
        let cfg = PoolConfig { engines: 2, ..PoolConfig::default() };
        let pool = EnginePool::spawn(pm, geom, 1, adapters, cfg).unwrap();
        let h = pool.handle();
        let ra = h.submit("a", vec![1, 2, 3], 4, u32::MAX).unwrap();
        let rb = h.submit("b", vec![4, 5], 3, u32::MAX).unwrap();
        assert_eq!(ra.tokens.len(), 4);
        assert_eq!(rb.tokens.len(), 3);
        assert_eq!(ra.task, "a");
        let unknown = h.submit("nope", vec![1], 2, u32::MAX).unwrap_err();
        assert!(matches!(unknown, ServeError::Failed(_)), "{unknown}");
        let m = pool.shutdown();
        assert_eq!(m.completed, 2);
        assert_eq!(m.generated_tokens, 7);
        assert_eq!(m.ttft_s.len(), 2);
        assert_eq!(m.shed_count, 0);
    }

    #[test]
    fn paged_pool_serves_and_reports_page_metrics() {
        let (pm, geom, adapters) = tiny_parts();
        let cfg = PoolConfig {
            engines: 2,
            window: 32,
            kv_pages: 6,
            page_tokens: 4,
            ..PoolConfig::default()
        };
        let pool = EnginePool::spawn(pm, geom, 1, adapters, cfg).unwrap();
        let h = pool.handle();
        let ra = h.submit("a", vec![1, 2, 3], 4, u32::MAX).unwrap();
        assert_eq!(ra.tokens.len(), 4);
        // 30 prompt + 64 new wraps the 32-token window, which spans 8
        // pages of 4 — more than the 6-page worker budget, so ingress
        // rejects it typed instead of queueing toward a worker failure.
        let err = h.submit("a", vec![9; 30], 64, u32::MAX).unwrap_err();
        assert!(matches!(err, ServeError::KvExhausted { .. }), "{err}");
        let m = pool.shutdown();
        assert_eq!(m.completed, 1);
        assert!(m.kv_pages_peak > 0, "paged backend never mapped a page");
        assert!(m.kv_pages_peak <= 6, "peak {} exceeds the pool", m.kv_pages_peak);
        assert_eq!(m.kv_exhausted_count, 1);
    }

    #[test]
    fn worker_clones_share_packed_codes() {
        let (pm, _geom, _adapters) = tiny_parts();
        // The property spawn_inner relies on: a model clone per worker
        // shares every packed code buffer with the original.
        let clone = pm.clone();
        let prefixes = pm.prefixes();
        assert!(!prefixes.is_empty());
        for p in &prefixes {
            let a = pm.matrix(p).unwrap();
            let b = clone.matrix(p).unwrap();
            assert!(a.codes_shared_with(b), "{p} codes were deep-copied");
        }
    }

    #[test]
    fn panicked_worker_poisons_nothing_and_pool_keeps_serving() {
        let (pm, geom, adapters) = tiny_parts();
        let cfg =
            PoolConfig { engines: 2, panic_on_task: Some("b"), ..PoolConfig::default() };
        let pool = EnginePool::spawn(pm, geom, 1, adapters, cfg).unwrap();
        let h = pool.handle();
        // One worker dies mid-burst holding the metrics lock. Its reply
        // sender drops with the stack frame, so the client gets a typed
        // error instead of a hang.
        let err = h.submit("b", vec![1, 2], 2, u32::MAX).unwrap_err();
        assert!(matches!(err, ServeError::Failed(_)), "{err}");
        // The mutex is now poisoned; without lock_clean every one of
        // these would cascade the panic. The surviving worker serves.
        for _ in 0..4 {
            let r = h.submit("a", vec![3, 4], 3, u32::MAX).unwrap();
            assert_eq!(r.tokens.len(), 3);
        }
        let m = h.metrics();
        assert!(m.completed >= 4, "metrics snapshot readable after poison: {}", m.completed);
        let m = pool.shutdown();
        assert!(m.completed >= 4, "completed = {}", m.completed);
    }

    #[test]
    fn pool_drop_without_shutdown_joins_workers() {
        let (pm, geom, adapters) = tiny_parts();
        let cfg = PoolConfig { engines: 2, ..PoolConfig::default() };
        let pool = EnginePool::spawn(pm, geom, 1, adapters, cfg).unwrap();
        let h = pool.handle();
        assert_eq!(h.submit("a", vec![7, 8], 2, u32::MAX).unwrap().tokens.len(), 2);
        drop(pool);
        // Admission is closed after drop; a late submit fails typed.
        assert!(h.submit("a", vec![1], 1, u32::MAX).is_err());
    }
}
