//! Threaded server wrapper around the single-threaded host [`Scheduler`].
//!
//! The same frontend/engine split as `coordinator::server` (the xla
//! path), applied to the host decode engine: the whole serving stack
//! (packed model + scratch arena + KV caches + scheduler queue) lives on
//! ONE worker thread; any number of concurrent clients talk to it over an
//! mpsc request channel without ever holding an engine lock. Requests
//! carry a oneshot-style reply channel.
//!
//! The worker collects each burst of queued messages before draining, so
//! requests submitted concurrently by different clients land in the
//! scheduler queue together — which is exactly what the scheduler's
//! cross-request prefill batching and task-greedy continuous batching
//! feed on: concurrency translates into larger fused GEMM batches, not
//! into contention.
//!
//! Unknown tasks are rejected at submit time (the scheduler's drain loop
//! never sees them), and a decode error fails the in-flight requests
//! instead of killing the worker. Dropping [`Server`] (or calling
//! [`Server::shutdown`]) stops the worker after the current drain.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::scheduler::Scheduler;
use super::types::{GenResponse, ServeMetrics};

enum Msg {
    Generate {
        task: String,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
        reply: mpsc::Sender<Result<GenResponse, String>>,
    },
    Metrics {
        reply: mpsc::Sender<ServeMetrics>,
    },
    Shutdown,
}

/// Client handle (cheaply cloneable; safe to move across threads).
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
}

impl ServerHandle {
    /// Blocking generate call: submits one request and waits for its
    /// response. Call from as many client threads as you like — the
    /// worker batches whatever arrives together.
    pub fn generate(
        &self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
    ) -> Result<GenResponse> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Generate { task: task.to_string(), prompt, max_new, stop, reply })
            .map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))?.map_err(|e| anyhow!(e))
    }

    /// Snapshot of the scheduler's accumulated [`ServeMetrics`].
    pub fn metrics(&self) -> Result<ServeMetrics> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Msg::Metrics { reply }).map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))
    }
}

/// Owning handle of the worker thread (see module docs).
pub struct Server {
    handle: ServerHandle,
    join: Option<JoinHandle<()>>,
}

impl Server {
    /// Move an already-built scheduler onto a dedicated worker thread and
    /// start serving.
    pub fn spawn(scheduler: Scheduler) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("peqa-serve".into())
            .spawn(move || worker_main(scheduler, rx))?;
        Ok(Server { handle: ServerHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn worker_main(mut sched: Scheduler, rx: mpsc::Receiver<Msg>) {
    let mut waiting: Vec<(u64, mpsc::Sender<Result<GenResponse, String>>)> = Vec::new();
    loop {
        // Block for at least one message; then drain whatever arrived —
        // the burst becomes one scheduler drain (continuous batching +
        // cross-request prefill over every request in it).
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return, // every handle dropped
        };
        let mut batch_msgs = vec![first];
        while let Ok(m) = rx.try_recv() {
            batch_msgs.push(m);
        }
        let mut shutdown = false;
        for m in batch_msgs {
            match m {
                Msg::Generate { task, prompt, max_new, stop, reply } => {
                    if !sched.has_task(&task) {
                        let _ = reply.send(Err(format!(
                            "no adapter registered for task '{task}'"
                        )));
                        continue;
                    }
                    let id = sched.submit(&task, prompt, max_new, stop);
                    waiting.push((id, reply));
                }
                Msg::Metrics { reply } => {
                    let _ = reply.send(sched.metrics.clone());
                }
                Msg::Shutdown => shutdown = true,
            }
        }
        if sched.pending() > 0 {
            match sched.run_until_idle() {
                Ok(responses) => {
                    for resp in responses {
                        if let Some(pos) = waiting.iter().position(|(id, _)| *id == resp.id) {
                            let (_, reply) = waiting.swap_remove(pos);
                            let _ = reply.send(Ok(resp));
                        }
                    }
                }
                Err(e) => {
                    // Every in-flight client gets the error — including
                    // ones whose requests were still queued behind the
                    // failing batch, so those must leave the scheduler
                    // queue too (decoding them later would burn steps on
                    // responses nobody is waiting for).
                    sched.clear_queue();
                    let msg = format!("decode failed: {e:#}");
                    for (_, reply) in waiting.drain(..) {
                        let _ = reply.send(Err(msg.clone()));
                    }
                }
            }
        }
        if shutdown {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{Engine, ModelGeom};
    use crate::serve::scheduler::SchedulerConfig;
    use crate::serve::{synth_adapters, synth_packed};

    fn tiny_scheduler() -> Scheduler {
        let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
        let (pm, base_q) = synth_packed(&geom, 4, None, 3).unwrap();
        let engine = Engine::from_packed(pm, geom, 2).unwrap();
        let adapters = synth_adapters(&base_q, &["a", "b"], 5);
        Scheduler::new(engine, adapters, SchedulerConfig::default()).unwrap()
    }

    #[test]
    fn serves_and_shuts_down() {
        let server = Server::spawn(tiny_scheduler()).unwrap();
        let h = server.handle();
        let r = h.generate("a", vec![1, 2, 3], 4, u32::MAX).unwrap();
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.task, "a");
        let m = h.metrics().unwrap();
        assert_eq!(m.completed, 1);
        server.shutdown();
        assert!(h.generate("a", vec![1], 1, u32::MAX).is_err());
    }

    #[test]
    fn unknown_task_fails_the_request_not_the_server() {
        let server = Server::spawn(tiny_scheduler()).unwrap();
        let h = server.handle();
        assert!(h.generate("nope", vec![1, 2], 3, u32::MAX).is_err());
        // The worker survives and keeps serving known tasks.
        let r = h.generate("b", vec![4, 5], 2, u32::MAX).unwrap();
        assert_eq!(r.tokens.len(), 2);
        server.shutdown();
    }
}
