//! Threaded server wrapper around the single-threaded host [`Scheduler`].
//!
//! The same frontend/engine split as `coordinator::server` (the xla
//! path), applied to the host decode engine: the whole serving stack
//! (packed model + scratch arena + KV caches + scheduler queue) lives on
//! ONE worker thread; any number of concurrent clients talk to it over an
//! mpsc request channel without ever holding an engine lock. Requests
//! carry a oneshot-style reply channel.
//!
//! The worker collects each burst of queued messages before draining, so
//! requests submitted concurrently by different clients land in the
//! scheduler queue together — which is exactly what the scheduler's
//! cross-request prefill batching and task-greedy continuous batching
//! feed on: concurrency translates into larger fused GEMM batches, not
//! into contention.
//!
//! Unknown tasks are rejected at submit time (the scheduler's drain loop
//! never sees them), and a decode error fails the in-flight requests
//! instead of killing the worker. Dropping [`Server`] (or calling
//! [`Server::shutdown`]) stops the worker after the current drain.
//! [`ServerHandle::submit_stream`] returns a bounded per-token
//! [`StreamEvent`] channel instead of a oneshot reply; the generated
//! tokens are bitwise identical either way. For multi-engine serving
//! see [`super::pool`] — this wrapper stays the one-engine path.
//!
//! **Adapter hot-reload**: [`Server::spawn_watching`] attaches a
//! [`Registry`] (`store::registry`). The worker polls the registry's
//! manifest generation at the start of every message burst — between
//! requests, never mid-decode — and when a new generation appears it
//! loads the checksummed adapters and swaps them in via
//! [`Scheduler::reload_adapters`] (always strict-validated). A bad
//! generation (torn file, checksum mismatch, partial coverage) is
//! rejected with a warning and the previous generation keeps serving;
//! that generation is not re-attempted until the publisher bumps again
//! or a client forces [`ServerHandle::reload`].

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::pool::STREAM_CHANNEL_CAP;
use super::scheduler::Scheduler;
use super::types::{AdapterStore, GenResponse, ServeError, ServeMetrics, StreamEvent};
use crate::store::Registry;

enum Msg {
    Generate {
        task: String,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
        reply: Reply,
    },
    Metrics {
        reply: mpsc::Sender<ServeMetrics>,
    },
    Reload {
        reply: mpsc::Sender<Result<u64, String>>,
    },
    Shutdown,
}

/// Where one request's outcome goes: a oneshot result channel
/// ([`ServerHandle::generate`]) or the client's [`StreamEvent`] channel
/// ([`ServerHandle::submit_stream`] — per-token events are streamed by
/// the scheduler; the worker appends the terminal `Done`/`Error`).
enum Reply {
    Oneshot(mpsc::Sender<Result<GenResponse, String>>),
    Stream(mpsc::SyncSender<StreamEvent>),
}

impl Reply {
    /// The scheduler-facing token sink (streaming replies only).
    fn sink(&self) -> Option<mpsc::SyncSender<StreamEvent>> {
        match self {
            Reply::Stream(tx) => Some(tx.clone()),
            Reply::Oneshot(_) => None,
        }
    }

    fn ok(self, resp: GenResponse) {
        match self {
            Reply::Oneshot(tx) => drop(tx.send(Ok(resp))),
            Reply::Stream(tx) => drop(tx.send(StreamEvent::Done(resp))),
        }
    }

    fn err(self, msg: String) {
        match self {
            Reply::Oneshot(tx) => drop(tx.send(Err(msg))),
            Reply::Stream(tx) => drop(tx.send(StreamEvent::Error(ServeError::Failed(msg)))),
        }
    }
}

/// Registry-watch state of a [`Server::spawn_watching`] worker.
struct RegistryWatch {
    registry: Registry,
    /// Last generation a reload was *attempted* for, successful or not —
    /// a rejected generation is warned about once, not every burst.
    last_attempted: u64,
    /// Generation currently serving.
    live: u64,
    /// Minimum ms between automatic polls (CLI `--watch-interval-ms`);
    /// 0 polls at every message burst. A forced reload ignores it.
    interval_ms: u64,
    last_poll: Instant,
}

impl RegistryWatch {
    /// Poll the registry and hot-reload if a new generation appeared
    /// (`force` re-attempts the current generation too). Returns the
    /// generation serving after the call; on error the scheduler's
    /// current adapters are untouched.
    fn poll(&mut self, sched: &mut Scheduler, force: bool) -> Result<u64, String> {
        if !force
            && self.interval_ms > 0
            && (self.last_poll.elapsed().as_millis() as u64) < self.interval_ms
        {
            return Ok(self.live);
        }
        // peqa-lint: allow(nondeterminism-sources) -- poll pacing only:
        // bounds how often the registry manifest is re-read.
        self.last_poll = Instant::now();
        let gen = self
            .registry
            .generation()
            .map_err(|e| format!("registry manifest: {e:#}"))?;
        if !force && gen == self.last_attempted {
            return Ok(self.live);
        }
        self.last_attempted = gen;
        let (g, pairs) = self.registry.load().map_err(|e| format!("registry load: {e:#}"))?;
        if pairs.is_empty() {
            return Err(format!("registry generation {g} has no published adapters"));
        }
        let mut store = AdapterStore::new();
        let n_tasks = pairs.len();
        for (task, ck) in pairs {
            store.insert(task, ck);
        }
        sched
            .reload_adapters(store)
            .map_err(|e| format!("adapter generation {g} rejected: {e:#}"))?;
        self.live = g;
        crate::info!("hot-reloaded adapter generation {g} ({n_tasks} task(s))");
        Ok(g)
    }
}

/// Client handle (cheaply cloneable; safe to move across threads).
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
}

impl ServerHandle {
    /// Blocking generate call: submits one request and waits for its
    /// response. Call from as many client threads as you like — the
    /// worker batches whatever arrives together.
    pub fn generate(
        &self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
    ) -> Result<GenResponse> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Generate {
                task: task.to_string(),
                prompt,
                max_new,
                stop,
                reply: Reply::Oneshot(reply),
            })
            .map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))?.map_err(|e| anyhow!(e))
    }

    /// Streaming generate: returns immediately with a bounded channel of
    /// [`StreamEvent`]s — one `Token` per accepted token the moment the
    /// decode loop accepts it, then exactly one `Done` (whose `tokens`
    /// are bitwise the concatenated `Token`s — streamed and
    /// non-streamed generations are identical) or `Error`. A client
    /// that stops draining eventually blocks the worker's decode batch
    /// (bounded-channel backpressure).
    pub fn submit_stream(
        &self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
    ) -> Result<mpsc::Receiver<StreamEvent>> {
        let (tx, rx) = mpsc::sync_channel(STREAM_CHANNEL_CAP);
        self.tx
            .send(Msg::Generate {
                task: task.to_string(),
                prompt,
                max_new,
                stop,
                reply: Reply::Stream(tx),
            })
            .map_err(|_| anyhow!("server is down"))?;
        Ok(rx)
    }

    /// Snapshot of the scheduler's accumulated [`ServeMetrics`].
    pub fn metrics(&self) -> Result<ServeMetrics> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Msg::Metrics { reply }).map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))
    }

    /// Force a registry poll right now (the worker also polls at every
    /// message burst). Returns the generation serving after the attempt;
    /// errors — including a rejected adapter set, which leaves the
    /// previous generation serving — are returned without killing the
    /// worker. Errors immediately if the server was not started with
    /// [`Server::spawn_watching`].
    pub fn reload(&self) -> Result<u64> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Msg::Reload { reply }).map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))?.map_err(|e| anyhow!(e))
    }
}

/// Owning handle of the worker thread (see module docs).
pub struct Server {
    handle: ServerHandle,
    join: Option<JoinHandle<()>>,
}

impl Server {
    /// Move an already-built scheduler onto a dedicated worker thread and
    /// start serving.
    pub fn spawn(scheduler: Scheduler) -> Result<Server> {
        Self::spawn_inner(scheduler, None)
    }

    /// [`Self::spawn`] plus a registry watch: the worker picks up newly
    /// published adapter generations between request bursts without a
    /// restart (see module docs). The registry's *current* generation is
    /// taken as the already-live baseline — callers typically built
    /// `scheduler` from it — so only a later publish (or a forced
    /// [`ServerHandle::reload`]) triggers a swap.
    pub fn spawn_watching(scheduler: Scheduler, registry: Registry) -> Result<Server> {
        Self::spawn_watching_interval(scheduler, registry, 0)
    }

    /// [`Self::spawn_watching`] with a minimum poll interval: automatic
    /// registry checks run at most once per `interval_ms` (0 = every
    /// message burst, the historical behavior). Forced
    /// [`ServerHandle::reload`] calls always poll.
    pub fn spawn_watching_interval(
        scheduler: Scheduler,
        registry: Registry,
        interval_ms: u64,
    ) -> Result<Server> {
        let gen = registry.generation().map_err(|e| {
            anyhow!("registry {} is unreadable: {e:#}", registry.dir().display())
        })?;
        let watch = RegistryWatch {
            registry,
            last_attempted: gen,
            live: gen,
            interval_ms,
            // peqa-lint: allow(nondeterminism-sources) -- poll pacing
            // only (see maybe_reload).
            last_poll: Instant::now(),
        };
        Self::spawn_inner(scheduler, Some(watch))
    }

    fn spawn_inner(scheduler: Scheduler, watch: Option<RegistryWatch>) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("peqa-serve".into())
            .spawn(move || worker_main(scheduler, rx, watch))?;
        Ok(Server { handle: ServerHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn worker_main(
    mut sched: Scheduler,
    rx: mpsc::Receiver<Msg>,
    mut watch: Option<RegistryWatch>,
) {
    let mut waiting: Vec<(u64, Reply)> = Vec::new();
    loop {
        // Block for at least one message; then drain whatever arrived —
        // the burst becomes one scheduler drain (continuous batching +
        // cross-request prefill over every request in it).
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return, // every handle dropped
        };
        let mut batch_msgs = vec![first];
        while let Ok(m) = rx.try_recv() {
            batch_msgs.push(m);
        }
        // Between bursts — before any of this burst's submits are
        // checked against the task set — pick up a newly published
        // adapter generation. A bad one is warned about once and the
        // previous generation keeps serving.
        if let Some(w) = watch.as_mut() {
            if let Err(e) = w.poll(&mut sched, false) {
                crate::warn!(
                    "adapter hot-reload skipped: {e} — still serving generation {}",
                    w.live
                );
            }
        }
        let mut shutdown = false;
        for m in batch_msgs {
            match m {
                Msg::Generate { task, prompt, max_new, stop, reply } => {
                    if !sched.has_task(&task) {
                        reply.err(format!("no adapter registered for task '{task}'"));
                        continue;
                    }
                    let sink = reply.sink();
                    match sched.submit_streaming(&task, prompt, max_new, stop, sink) {
                        Ok(id) => waiting.push((id, reply)),
                        // Typed submit-time rejects (PromptTooLong,
                        // KvExhausted): the request never entered the
                        // queue, so only this client hears about it.
                        Err(e) => reply.err(e.to_string()),
                    }
                }
                Msg::Metrics { reply } => {
                    let _ = reply.send(sched.metrics.clone());
                }
                Msg::Reload { reply } => {
                    let res = match watch.as_mut() {
                        Some(w) => w.poll(&mut sched, true),
                        None => Err(
                            "server is not watching a registry (serve with --registry)"
                                .to_string(),
                        ),
                    };
                    let _ = reply.send(res);
                }
                Msg::Shutdown => shutdown = true,
            }
        }
        if sched.pending() > 0 {
            match sched.run_until_idle() {
                Ok(responses) => {
                    for resp in responses {
                        if let Some(pos) = waiting.iter().position(|(id, _)| *id == resp.id) {
                            let (_, reply) = waiting.swap_remove(pos);
                            reply.ok(resp);
                        }
                    }
                }
                Err(e) => {
                    // Every in-flight client gets the error — including
                    // ones whose requests were still queued behind the
                    // failing batch, so those must leave the scheduler
                    // queue too (decoding them later would burn steps on
                    // responses nobody is waiting for).
                    sched.clear_queue();
                    let msg = format!("decode failed: {e:#}");
                    for (_, reply) in waiting.drain(..) {
                        reply.err(msg.clone());
                    }
                }
            }
        }
        if shutdown {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{Engine, ModelGeom};
    use crate::serve::scheduler::SchedulerConfig;
    use crate::serve::{synth_adapters, synth_packed};

    fn tiny_scheduler() -> Scheduler {
        let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
        let (pm, base_q) = synth_packed(&geom, 4, None, 3).unwrap();
        let engine = Engine::from_packed(pm, geom, 2).unwrap();
        let adapters = synth_adapters(&base_q, &["a", "b"], 5);
        Scheduler::new(engine, adapters, SchedulerConfig::default()).unwrap()
    }

    #[test]
    fn serves_and_shuts_down() {
        let server = Server::spawn(tiny_scheduler()).unwrap();
        let h = server.handle();
        let r = h.generate("a", vec![1, 2, 3], 4, u32::MAX).unwrap();
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.task, "a");
        let m = h.metrics().unwrap();
        assert_eq!(m.completed, 1);
        server.shutdown();
        assert!(h.generate("a", vec![1], 1, u32::MAX).is_err());
    }

    #[test]
    fn streamed_tokens_match_nonstreaming_generate() {
        use crate::serve::types::collect_stream;
        let server = Server::spawn(tiny_scheduler()).unwrap();
        let h = server.handle();
        let direct = h.generate("a", vec![1, 2, 3], 5, u32::MAX).unwrap();
        assert_eq!(direct.tokens.len(), 5);
        let rx = h.submit_stream("a", vec![1, 2, 3], 5, u32::MAX).unwrap();
        let (tokens, done) = collect_stream(&rx).unwrap();
        assert_eq!(tokens, direct.tokens, "streamed decode must be bitwise the same");
        assert_eq!(done.tokens, direct.tokens);
        assert!(done.id != direct.id);
        // An unknown task surfaces as a terminal Error event.
        let rx = h.submit_stream("nope", vec![1], 2, u32::MAX).unwrap();
        assert!(collect_stream(&rx).is_err());
        server.shutdown();
    }

    #[test]
    fn hot_reload_picks_up_new_generation_and_rejects_bad_ones() {
        use crate::model::Checkpoint;
        use crate::store::Registry;
        let dir = std::env::temp_dir().join("peqa_test_server_registry");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let reg = Registry::open(&dir);

        // Scheduler + a matching full-coverage adapter source.
        let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
        let (pm, base_q) = synth_packed(&geom, 4, None, 3).unwrap();
        let full = base_q.extract_adapter(true);
        let engine = Engine::from_packed(pm, geom, 2).unwrap();
        let adapters = synth_adapters(&base_q, &["a"], 5);
        let sched = Scheduler::new(engine, adapters, SchedulerConfig::default()).unwrap();

        let server = Server::spawn_watching(sched, Registry::open(&dir)).unwrap();
        let h = server.handle();
        assert!(h.generate("a", vec![1, 2], 2, u32::MAX).is_ok());
        assert!(h.generate("fresh", vec![1], 1, u32::MAX).is_err());

        // Publish generation 1; the very next burst serves it — no
        // restart, no explicit reload call.
        assert_eq!(reg.publish(&[("fresh".to_string(), &full)]).unwrap(), 1);
        let r = h.generate("fresh", vec![1, 2, 3], 2, u32::MAX).unwrap();
        assert_eq!(r.tokens.len(), 2);
        assert!(h.generate("a", vec![1], 1, u32::MAX).is_err(), "old set replaced");

        // Generation 2 contains a partial-coverage adapter: the whole
        // generation is rejected and generation 1 keeps serving.
        let s_name = full.names().iter().find(|n| n.ends_with(".s")).unwrap().clone();
        let mut partial = Checkpoint::new();
        partial.insert(s_name.clone(), full.req(&s_name).unwrap().clone());
        assert_eq!(reg.publish(&[("broken".to_string(), &partial)]).unwrap(), 2);
        let err = h.reload().unwrap_err().to_string();
        assert!(err.contains("rejected"), "{err}");
        assert!(h.generate("fresh", vec![4, 5], 2, u32::MAX).is_ok());
        assert!(h.generate("broken", vec![1], 1, u32::MAX).is_err());

        // Generation 3 fixes it; the forced reload reports the new
        // generation and both tasks serve.
        assert_eq!(reg.publish(&[("broken".to_string(), &full)]).unwrap(), 3);
        assert_eq!(h.reload().unwrap(), 3);
        assert!(h.generate("broken", vec![2, 3], 2, u32::MAX).is_ok());
        assert!(h.generate("fresh", vec![2], 1, u32::MAX).is_ok());
        server.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reload_without_registry_is_an_error_not_a_crash() {
        let server = Server::spawn(tiny_scheduler()).unwrap();
        let h = server.handle();
        let err = h.reload().unwrap_err().to_string();
        assert!(err.contains("not watching a registry"), "{err}");
        assert!(h.generate("a", vec![1, 2], 2, u32::MAX).is_ok());
        server.shutdown();
    }

    #[test]
    fn unknown_task_fails_the_request_not_the_server() {
        let server = Server::spawn(tiny_scheduler()).unwrap();
        let h = server.handle();
        assert!(h.generate("nope", vec![1, 2], 3, u32::MAX).is_err());
        // The worker survives and keeps serving known tasks.
        let r = h.generate("b", vec![4, 5], 2, u32::MAX).unwrap();
        assert_eq!(r.tokens.len(), 2);
        server.shutdown();
    }
}
