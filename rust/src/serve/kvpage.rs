//! Paged KV memory — fixed-size pages, a word-scan bitmap allocator,
//! and copy-on-write prompt-prefix sharing.
//!
//! The ring buffers in [`super::kvcache`] preallocate `window × layers ×
//! d` floats per sequence, so serving RAM scales as worst-case context ×
//! concurrency even when most requests use a fraction of the window.
//! This module decouples the two, vLLM-style:
//!
//! * [`KvPage`] — one fixed-size block of K/V storage holding
//!   `page_tokens` positions for every layer.
//! * [`PageAllocator`] — a free-page bitmap (one bit per page, word-scan
//!   with a rotating hint, modeled on segment-validity tables from
//!   log-structured storage) handing out page ids from one pool sized by
//!   `--kv-pages`.
//! * [`PagePool`] — the allocator plus per-page refcounts, recycled page
//!   buffers, a reservation counter (admission promises pages up front
//!   so concurrent sequences can never over-commit the pool mid-decode),
//!   and a hash-indexed prefix trie keyed on `(task, parent, token
//!   chunk)` that lets same-task requests attach already-written prompt
//!   pages instead of re-prefilling them.
//! * [`PagedKvCache`] — the per-sequence page table: logical position →
//!   page, same ring semantics as [`super::kvcache::KvCache`] (slot =
//!   `abs % capacity`, sliding window past capacity), storage allocated
//!   page-by-page as the sequence actually grows.
//!
//! ## Copy-on-write contract
//!
//! Shared pages are always *complete* prompt chunks (exactly
//! `page_tokens` tokens), attached read-only by later same-task
//! requests; a sequence writes into a shared page only when its ring
//! wraps back onto it. [`PagedKvCache::prepare`] runs on the scheduler
//! thread before every engine call and un-shares (allocates + copies)
//! any page about to be written, so engine worker threads only ever
//! write pages they own uniquely. [`std::sync::Arc::make_mut`] in the
//! write path is the panic-free backstop: if `prepare` was somehow
//! skipped the decode stays bitwise correct (the write clones privately)
//! and only the pool accounting goes stale.
//!
//! ## Bitwise parity with the ring
//!
//! A paged sequence stores exactly the rows the ring stores, at the same
//! ring slots; [`PagedKvCache::window_segments`] walks the attention
//! window in ascending position order as ≤ `window/page_tokens + 1`
//! contiguous segments. The attention kernel's per-(head, position)
//! arithmetic is independent of slab segmentation, so paged decode is
//! bitwise identical to the ring reference at any page size, thread
//! count, and sharing pattern — the ring stays in-tree as the oracle.

use std::collections::HashMap;
use std::sync::Arc;

/// Default tokens per page (CLI `--page-tokens`).
pub const DEFAULT_PAGE_TOKENS: usize = 16;

/// One fixed-size KV page: K and V storage for `page_tokens` positions
/// × `n_layers` layers × `d` floats. Row `(layer, slot)` lives at
/// `(layer * page_tokens + slot) * d`.
#[derive(Clone, Debug)]
pub struct KvPage {
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvPage {
    fn new(n_layers: usize, page_tokens: usize, d: usize) -> KvPage {
        let n = n_layers * page_tokens * d;
        KvPage { k: vec![0.0; n], v: vec![0.0; n] }
    }

    /// Bytes of K+V storage.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }
}

/// Free-page bitmap: bit set = page free. Allocation word-scans from a
/// rotating hint (O(words) worst case, O(1) amortized); free flips one
/// bit. Double-free and out-of-range are reported, never panicked on.
#[derive(Clone, Debug)]
pub struct PageAllocator {
    words: Vec<u64>,
    total: usize,
    free: usize,
    hint: usize,
}

impl PageAllocator {
    pub fn new(total: usize) -> PageAllocator {
        let n_words = total.div_ceil(64);
        let mut words = vec![u64::MAX; n_words];
        if total % 64 != 0 {
            if let Some(last) = words.last_mut() {
                *last = (1u64 << (total % 64)) - 1;
            }
        }
        PageAllocator { words, total, free: total, hint: 0 }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn free_count(&self) -> usize {
        self.free
    }

    /// Hand out a free page id, or `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<u32> {
        if self.free == 0 {
            return None;
        }
        let n = self.words.len();
        for i in 0..n {
            let w = (self.hint + i) % n;
            let word = self.words[w];
            if word != 0 {
                let bit = word.trailing_zeros() as usize;
                self.words[w] = word & !(1u64 << bit);
                self.hint = w;
                self.free -= 1;
                return Some((w * 64 + bit) as u32);
            }
        }
        None
    }

    /// Return a page to the pool. `false` means double-free or
    /// out-of-range — the bitmap is left unchanged (the caller treats it
    /// as a logic error; nothing is ever handed out twice).
    pub fn free(&mut self, id: u32) -> bool {
        let id = id as usize;
        if id >= self.total {
            return false;
        }
        let (w, bit) = (id / 64, id % 64);
        if self.words[w] & (1u64 << bit) != 0 {
            return false;
        }
        self.words[w] |= 1u64 << bit;
        self.free += 1;
        true
    }

    pub fn is_free(&self, id: u32) -> bool {
        let id = id as usize;
        id < self.total && self.words[id / 64] & (1u64 << (id % 64)) != 0
    }
}

/// Pool occupancy counters (see [`PagePool::stats`]). `shared_attached`
/// is a cumulative event counter drained into `ServeMetrics` by the
/// scheduler ([`PagePool::take_shared_count`]); `in_use`/`peak` are
/// levels.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// Pages currently allocated (distinct ids handed out).
    pub in_use: usize,
    /// High-water mark of `in_use` over the pool's lifetime.
    pub peak: usize,
    /// Shared prompt pages attached by later requests (each attach of
    /// one page counts once) — the savings counter.
    pub shared_attached: usize,
}

/// Exact trie key: a prompt chunk is shared only between requests of
/// the same task whose prompts agree token-for-token up to and
/// including this chunk (`parent` chains the preceding chunk's node).
#[derive(Clone, Debug, Hash, PartialEq, Eq)]
struct PrefixKey {
    task: String,
    parent: Option<usize>,
    chunk: Vec<u32>,
}

#[derive(Debug)]
struct TrieNode {
    key: PrefixKey,
    /// The shared page, set by [`PagePool::publish_ready`] *after* the
    /// registering request's prefill wrote it. While `None` the node
    /// only marks the key as pending (matching requests defer), and the
    /// writer's page keeps refcount 1 so its own `prepare` never
    /// copy-on-writes the page it is about to fill.
    page: Option<(u32, Arc<KvPage>)>,
    /// False while the registering request's prefill is still in flight
    /// this admit pass; matching requests defer instead of attaching.
    ready: bool,
    /// Sequences currently holding this node (writer + attachers). At
    /// zero the node is removed and its page reference dropped.
    live: usize,
}

/// One page-table entry: the pool id plus the shared storage handle.
#[derive(Debug)]
struct Entry {
    id: u32,
    page: Arc<KvPage>,
}

/// Transient page shortage surfaced by [`PagedKvCache::prepare`] — with
/// correct admission reservations it cannot fire; it exists so the
/// serve path stays panic-free even against accounting bugs.
#[derive(Clone, Debug)]
pub struct KvPressure {
    pub need: usize,
    pub available: usize,
}

impl std::fmt::Display for KvPressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "kv page pool under-reserved: need {} page(s), {} available",
            self.need, self.available
        )
    }
}

impl std::error::Error for KvPressure {}

/// Outcome of [`PagePool::admit_seq`].
pub enum SeqAdmit {
    /// Staffed: the cache starts at `pos() == shared_tokens` — the
    /// engine prefills only `prompt[cache.pos()..]`.
    Ready(PagedKvCache),
    /// The prompt prefix matches pages another request registered in
    /// this very admit pass; retry after that request's prefill flips
    /// them ready (only returned when `allow_defer`).
    Defer,
    /// Not enough unreserved free pages right now — leave the request
    /// queued; finishing sequences will free pages.
    NoPages { need: usize, available: usize },
    /// The request can never fit the pool even alone — reject with a
    /// typed error at submit/admit instead of over-admitting.
    Never { need: usize, total: usize },
}

/// The shared page pool of one scheduler/worker (single-threaded
/// access; engine worker threads never touch it — see module docs).
#[derive(Debug)]
pub struct PagePool {
    n_layers: usize,
    d: usize,
    page_tokens: usize,
    alloc: PageAllocator,
    /// Per-id reference count: table entries + trie nodes. 0 = free.
    refs: Vec<u32>,
    /// Recycled page buffers (page recycling replaces the scheduler's
    /// old capacity-keyed spare-cache pool).
    spares: Vec<KvPage>,
    /// Pages promised to admitted-but-not-yet-grown sequences.
    reserved: usize,
    stats: PoolStats,
    nodes: Vec<Option<TrieNode>>,
    free_nodes: Vec<usize>,
    index: HashMap<PrefixKey, usize>,
}

impl PagePool {
    /// `d` is the per-position KV row width (n_heads · head_dim).
    pub fn new(n_layers: usize, d: usize, page_tokens: usize, total_pages: usize) -> PagePool {
        PagePool {
            n_layers,
            d,
            page_tokens: page_tokens.max(1),
            alloc: PageAllocator::new(total_pages),
            refs: vec![0; total_pages],
            spares: Vec::new(),
            reserved: 0,
            stats: PoolStats::default(),
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            index: HashMap::new(),
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn total_pages(&self) -> usize {
        self.alloc.total()
    }

    pub fn free_pages(&self) -> usize {
        self.alloc.free_count()
    }

    /// Free pages not yet promised to an admitted sequence.
    pub fn available(&self) -> usize {
        self.alloc.free_count().saturating_sub(self.reserved)
    }

    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Drain the cumulative shared-attach counter (delta reporting into
    /// `ServeMetrics`, which adds across harvests).
    pub fn take_shared_count(&mut self) -> usize {
        std::mem::take(&mut self.stats.shared_attached)
    }

    /// Worst-case distinct pages a request may come to own privately:
    /// `ceil((prompt+max_new)/P)` capped at the table length
    /// `ceil(capacity/P)` (past that the ring overwrites in place).
    pub fn demand_pages(&self, prompt_len: usize, max_new: usize, capacity: usize) -> usize {
        let table_len = capacity.div_ceil(self.page_tokens);
        (prompt_len + max_new).div_ceil(self.page_tokens).min(table_len)
    }

    /// Submit-time feasibility: `Some((need, total))` when the request
    /// could never fit the pool even with every page free. Sharing can
    /// only reduce the real footprint, never the worst case (shared
    /// pages un-share on ring wrap), so this is the one rejection that
    /// is safe to issue before seeing the pool's future state.
    pub fn never_fits(
        &self,
        prompt_len: usize,
        max_new: usize,
        capacity: usize,
    ) -> Option<(usize, usize)> {
        let need = self.demand_pages(prompt_len, max_new, capacity);
        if need > self.alloc.total() {
            Some((need, self.alloc.total()))
        } else {
            None
        }
    }

    fn ref_count(&self, id: u32) -> u32 {
        self.refs.get(id as usize).copied().unwrap_or(0)
    }

    /// Allocate one page: bitmap id + a recycled (or fresh) buffer.
    fn alloc_page(&mut self) -> Option<(u32, Arc<KvPage>)> {
        let id = self.alloc.alloc()?;
        let buf = match self.spares.pop() {
            Some(b) => b,
            None => KvPage::new(self.n_layers, self.page_tokens, self.d),
        };
        if let Some(r) = self.refs.get_mut(id as usize) {
            *r = 1;
        }
        self.stats.in_use += 1;
        if self.stats.in_use > self.stats.peak {
            self.stats.peak = self.stats.in_use;
        }
        Some((id, Arc::new(buf)))
    }

    /// Allocate against a sequence's reservation, falling back to
    /// unreserved free pages when the reservation is spent.
    fn alloc_reserved(&mut self, reservation: &mut usize) -> Option<(u32, Arc<KvPage>)> {
        if *reservation > 0 {
            *reservation -= 1;
            self.reserved = self.reserved.saturating_sub(1);
        } else if self.available() == 0 {
            return None;
        }
        self.alloc_page()
    }

    fn incref(&mut self, id: u32) {
        if let Some(r) = self.refs.get_mut(id as usize) {
            *r += 1;
        }
    }

    /// Drop one reference; the last reference frees the bitmap slot and
    /// recycles the buffer when this was the last `Arc` holder.
    fn decref(&mut self, id: u32, page: Arc<KvPage>) {
        let Some(r) = self.refs.get_mut(id as usize) else { return };
        if *r == 0 {
            return;
        }
        *r -= 1;
        if *r == 0 {
            if self.alloc.free(id) {
                self.stats.in_use = self.stats.in_use.saturating_sub(1);
            }
            if let Some(buf) = Arc::into_inner(page) {
                self.spares.push(buf);
            }
        }
    }

    fn insert_node(&mut self, node: TrieNode) -> usize {
        match self.free_nodes.pop() {
            Some(ni) => {
                self.nodes[ni] = Some(node);
                ni
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        }
    }

    fn release_node(&mut self, ni: usize) {
        let dead = match self.nodes.get_mut(ni).and_then(|s| s.as_mut()) {
            Some(nd) => {
                nd.live = nd.live.saturating_sub(1);
                nd.live == 0
            }
            None => false,
        };
        if dead {
            if let Some(nd) = self.nodes[ni].take() {
                self.index.remove(&nd.key);
                self.free_nodes.push(ni);
                if let Some((id, page)) = nd.page {
                    self.decref(id, page);
                }
            }
        }
    }

    /// Admission: reserve worst-case pages, attach any already-written
    /// shared prefix chain, and register this prompt's own full chunks
    /// as pending trie nodes so same-burst requests can share them (see
    /// [`SeqAdmit`]). Runs on the scheduler thread only.
    pub fn admit_seq(
        &mut self,
        task: &str,
        prompt: &[u32],
        max_new: usize,
        capacity: usize,
        allow_defer: bool,
    ) -> SeqAdmit {
        let p = self.page_tokens;
        let table_len = capacity.div_ceil(p);
        if let Some((need, total)) = self.never_fits(prompt.len(), max_new, capacity) {
            return SeqAdmit::Never { need, total };
        }
        // Walk the trie over the attachable chunks: at most
        // (len-1)/P, so the sequence always prefills ≥ 1 tail token
        // (it needs its own last-prompt-row logits to sample from).
        let attach_max = if prompt.is_empty() { 0 } else { (prompt.len() - 1) / p };
        let mut matched: Vec<usize> = Vec::new();
        let mut parent = None;
        for ci in 0..attach_max {
            let key = PrefixKey {
                task: task.to_string(),
                parent,
                chunk: prompt[ci * p..(ci + 1) * p].to_vec(),
            };
            match self.index.get(&key) {
                Some(&ni) => match self.nodes.get(ni).and_then(|s| s.as_ref()) {
                    Some(nd) if nd.ready && nd.page.is_some() => {
                        matched.push(ni);
                        parent = Some(ni);
                    }
                    _ => {
                        // Pending: registered earlier in this very admit
                        // pass; its prefill flips it ready momentarily.
                        if allow_defer {
                            return SeqAdmit::Defer;
                        }
                        break;
                    }
                },
                None => break,
            }
        }
        let shared = matched.len();
        // Worst-case *new* pages: a wrapping sequence may un-share every
        // attached page, so sharing only discounts the non-wrap case.
        let wraps = prompt.len() + max_new > capacity;
        let need = if wraps {
            table_len
        } else {
            self.demand_pages(prompt.len(), max_new, capacity).saturating_sub(shared)
        };
        if need > self.available() {
            return SeqAdmit::NoPages { need, available: self.available() };
        }
        self.reserved += need;
        let mut cache = PagedKvCache {
            n_layers: self.n_layers,
            d: self.d,
            capacity,
            page_tokens: p,
            pos: shared * p,
            table: (0..table_len).map(|_| None).collect(),
            held_nodes: Vec::new(),
            registered: Vec::new(),
            reservation: need,
        };
        for (pi, &ni) in matched.iter().enumerate() {
            if let Some(nd) = self.nodes[ni].as_mut() {
                if let Some((id, page)) = nd.page.clone() {
                    nd.live += 1;
                    self.incref(id);
                    cache.table[pi] = Some(Entry { id, page });
                    cache.held_nodes.push(ni);
                }
            }
        }
        self.stats.shared_attached += shared;
        // Writer path: allocate this prompt's remaining full chunks now
        // (privately — refcount 1, so the writer's own `prepare` never
        // copy-on-writes them) and register them pending; requests later
        // in this burst defer-attach instead of re-prefilling the prefix.
        let full_chunks = prompt.len() / p;
        let mut reg_parent = matched.last().copied();
        for ci in shared..full_chunks {
            let key = PrefixKey {
                task: task.to_string(),
                parent: reg_parent,
                chunk: prompt[ci * p..(ci + 1) * p].to_vec(),
            };
            if self.index.contains_key(&key) {
                // Forced-miss duplicate (defer was disallowed): keep the
                // pages private, stop registering deeper chunks.
                break;
            }
            let Some((id, page)) = self.alloc_reserved(&mut cache.reservation) else {
                break; // reservation spent — skip sharing, stay correct
            };
            cache.table[ci] = Some(Entry { id, page });
            let ni = self.insert_node(TrieNode {
                key: key.clone(),
                page: None,
                ready: false,
                live: 1,
            });
            self.index.insert(key, ni);
            cache.held_nodes.push(ni);
            cache.registered.push((ni, ci));
            reg_parent = Some(ni);
        }
        SeqAdmit::Ready(cache)
    }

    /// Flip the chunks `cache` registered in [`Self::admit_seq`] to
    /// ready — call right after the sequence's prefill wrote them. Only
    /// now does each trie node take its page reference (refcount 2:
    /// writer table + node), so attachers see exactly the written rows.
    pub fn publish_ready(&mut self, cache: &mut PagedKvCache) {
        for (ni, pi) in cache.registered.drain(..) {
            let Some(e) = cache.table.get(pi).and_then(|e| e.as_ref()) else {
                continue;
            };
            let (id, page) = (e.id, e.page.clone());
            if let Some(nd) = self.nodes.get_mut(ni).and_then(|s| s.as_mut()) {
                if let Some(r) = self.refs.get_mut(id as usize) {
                    *r += 1;
                }
                nd.page = Some((id, page));
                nd.ready = true;
            }
        }
    }

    /// Return every page and trie reference a finished sequence holds
    /// (page recycling on completion).
    pub fn release_seq(&mut self, cache: &mut PagedKvCache) {
        // Children before parents: a node's chain parents always outlive
        // it, and held_nodes is chain-ordered root-first.
        for ni in cache.held_nodes.drain(..).rev() {
            self.release_node(ni);
        }
        cache.registered.clear();
        for e in cache.table.iter_mut().filter_map(Option::take) {
            self.decref(e.id, e.page);
        }
        self.reserved = self.reserved.saturating_sub(cache.reservation);
        cache.reservation = 0;
        cache.pos = 0;
    }
}

/// Per-sequence page table over a [`PagePool`] — the paged replacement
/// for [`super::kvcache::KvCache`], same ring semantics (module docs).
#[derive(Debug)]
pub struct PagedKvCache {
    n_layers: usize,
    d: usize,
    capacity: usize,
    page_tokens: usize,
    /// Absolute sequence length appended so far (monotonic; slots ring).
    pos: usize,
    table: Vec<Option<Entry>>,
    held_nodes: Vec<usize>,
    /// `(trie node, table index)` of chunks this sequence registered
    /// pending — drained by [`PagePool::publish_ready`].
    registered: Vec<(usize, usize)>,
    reservation: usize,
}

impl PagedKvCache {
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn len(&self) -> usize {
        self.pos.min(self.capacity)
    }

    pub fn is_empty(&self) -> bool {
        self.pos == 0
    }

    pub fn window_len(&self, abs: usize) -> usize {
        (abs + 1).min(self.capacity)
    }

    /// Pages currently mapped by this sequence (shared + private).
    pub fn pages_mapped(&self) -> usize {
        self.table.iter().filter(|e| e.is_some()).count()
    }

    /// Make the next `n_tokens` positions writable: allocate boundary
    /// pages and un-share (allocate + copy) any shared page the ring is
    /// about to overwrite. Must run on the scheduler thread, before the
    /// engine call that writes those positions.
    pub fn prepare(&mut self, pool: &mut PagePool, n_tokens: usize) -> Result<(), KvPressure> {
        let p = self.page_tokens;
        let cap = self.capacity;
        let mut pos = self.pos;
        let end = self.pos + n_tokens;
        while pos < end {
            let slot = pos % cap;
            let pi = slot / p;
            let run = (p - slot % p).min(cap - slot).min(end - pos);
            let needs_page = match &self.table[pi] {
                None => true,
                Some(e) => pool.ref_count(e.id) > 1,
            };
            if needs_page {
                let Some((id, mut page)) = pool.alloc_reserved(&mut self.reservation) else {
                    return Err(KvPressure { need: 1, available: pool.available() });
                };
                if let Some(old) = self.table[pi].take() {
                    // Copy-on-write: carry the shared rows into the
                    // private copy — the ring overwrites only some of
                    // them, the rest stay attendable in the window.
                    if let Some(pm) = Arc::get_mut(&mut page) {
                        pm.k.copy_from_slice(&old.page.k);
                        pm.v.copy_from_slice(&old.page.v);
                    }
                    pool.decref(old.id, old.page);
                }
                self.table[pi] = Some(Entry { id, page });
            }
            pos += run;
        }
        Ok(())
    }

    /// Store the K/V rows of absolute position `abs` for `layer` (same
    /// contract as the ring's `write`). The target page is unique after
    /// [`Self::prepare`]; `Arc::make_mut` keeps this panic-free (and
    /// bitwise correct) even if it is unexpectedly still shared.
    pub fn write(&mut self, layer: usize, abs: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        let p = self.page_tokens;
        let slot = abs % self.capacity;
        let pi = slot / p;
        debug_assert!(self.table[pi].is_some(), "write before prepare at abs={abs}");
        if let Some(e) = self.table[pi].as_mut() {
            let pg = Arc::make_mut(&mut e.page);
            let o = (layer * p + slot % p) * self.d;
            pg.k[o..o + self.d].copy_from_slice(k);
            pg.v[o..o + self.d].copy_from_slice(v);
        }
    }

    /// The attention window of a query at absolute position `abs` as an
    /// iterator of contiguous `(k, v)` row segments in ascending
    /// position order — ≤ `capacity/page_tokens + 1` of them (one per
    /// page touched, plus one extra split where the ring wraps). Row `j`
    /// of the concatenation is position `abs + 1 − window_len(abs) + j`,
    /// exactly the ring's `window_slabs` contract.
    pub fn window_segments(&self, layer: usize, abs: usize) -> PageWalk<'_> {
        let n = self.window_len(abs);
        PageWalk { cache: self, layer, pos: abs + 1 - n, end: abs + 1 }
    }

    /// Mark `n` more positions as fully appended (all layers written).
    pub fn advance(&mut self, n: usize) {
        self.pos += n;
    }

    /// Bytes of page storage currently mapped by this sequence.
    pub fn bytes(&self) -> usize {
        self.table
            .iter()
            .flatten()
            .map(|e| e.page.bytes())
            .sum()
    }
}

/// Iterator behind [`PagedKvCache::window_segments`] — computes each
/// contiguous segment on the fly, no allocation (the attention kernel
/// clones it for its two passes).
#[derive(Clone)]
pub struct PageWalk<'a> {
    cache: &'a PagedKvCache,
    layer: usize,
    pos: usize,
    end: usize,
}

impl<'a> Iterator for PageWalk<'a> {
    type Item = (&'a [f32], &'a [f32]);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.end {
            return None;
        }
        let c = self.cache;
        let p = c.page_tokens;
        let slot = self.pos % c.capacity;
        let pi = slot / p;
        let in_page = slot % p;
        let run = (p - in_page).min(c.capacity - slot).min(self.end - self.pos);
        self.pos += run;
        // A missing entry is a prepare/write ordering bug; ending the
        // walk early is the panic-free response (caught by the parity
        // suites, which compare against the ring oracle bitwise).
        let e = c.table[pi].as_ref()?;
        let base = (self.layer * p + in_page) * c.d;
        let len = run * c.d;
        Some((&e.page.k[base..base + len], &e.page.v[base..base + len]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;
    use std::collections::HashSet;

    #[test]
    fn bitmap_alloc_free_fuzz_never_double_hands_a_page() {
        let total = 173; // off 64-boundary on purpose
        let mut a = PageAllocator::new(total);
        let mut held: HashSet<u32> = HashSet::new();
        let mut rng = Pcg32::seeded(0xf42, 7);
        for op in 0..2500 {
            if rng.f32() < 0.55 {
                match a.alloc() {
                    Some(id) => {
                        assert!((id as usize) < total, "op {op}: id {id} out of range");
                        assert!(held.insert(id), "op {op}: page {id} double-handed");
                        assert!(!a.is_free(id));
                    }
                    None => assert_eq!(held.len(), total, "op {op}: spurious exhaustion"),
                }
            } else if let Some(&id) = held.iter().next() {
                held.remove(&id);
                assert!(a.free(id), "op {op}: legitimate free rejected");
                assert!(a.is_free(id));
                // Double-free must be reported and change nothing.
                assert!(!a.free(id), "op {op}: double-free accepted");
            }
            assert_eq!(a.free_count(), total - held.len(), "op {op}: free count drifted");
        }
        // Drain everything back and verify the pool is whole again.
        for id in held.drain() {
            assert!(a.free(id));
        }
        assert_eq!(a.free_count(), total);
        assert!(!a.free(total as u32), "out-of-range free accepted");
    }

    #[test]
    fn bitmap_exhausts_exactly_and_recovers() {
        let mut a = PageAllocator::new(5);
        let ids: Vec<u32> = (0..5).map(|_| a.alloc().unwrap()).collect();
        assert_eq!(a.alloc(), None);
        assert_eq!(a.free_count(), 0);
        assert!(a.free(ids[2]));
        assert_eq!(a.alloc(), Some(ids[2]));
        assert_eq!(a.alloc(), None);
    }

    fn row(tag: f32, d: usize) -> Vec<f32> {
        (0..d).map(|j| tag + j as f32).collect()
    }

    /// Admit a lone sequence (no sharing) and fill `n_tokens` positions.
    fn grow(
        pool: &mut PagePool,
        cache: &mut PagedKvCache,
        layers: usize,
        d: usize,
        from: usize,
        to: usize,
    ) {
        for t in from..to {
            cache.prepare(pool, 1).unwrap();
            for layer in 0..layers {
                let tag = (1000 * layer + t) as f32;
                cache.write(layer, t, &row(tag, d), &row(tag + 0.5, d));
            }
            cache.advance(1);
        }
    }

    #[test]
    fn page_walk_matches_ring_rows_in_position_order() {
        // Mirror kvcache's window_slabs test: every (layer, abs) window
        // must concatenate to the written rows in ascending positions —
        // including after the ring wraps and with capacity % P != 0.
        let (layers, d, cap, p) = (2usize, 3usize, 10usize, 4usize);
        let mut pool = PagePool::new(layers, d, p, 16);
        let SeqAdmit::Ready(mut c) = pool.admit_seq("t", &[], 0, cap, false) else {
            panic!("admit failed")
        };
        grow(&mut pool, &mut c, layers, d, 0, 17);
        for layer in 0..layers {
            for abs in [0usize, 3, 4, 9, 10, 13, 16] {
                let n = c.window_len(abs);
                let start = abs + 1 - n;
                let mut rows: Vec<f32> = Vec::new();
                let mut segs = 0;
                for (k, _v) in c.window_segments(layer, abs) {
                    rows.extend_from_slice(k);
                    segs += 1;
                }
                assert!(segs <= cap.div_ceil(p) + 1, "abs={abs}: {segs} segments");
                assert_eq!(rows.len(), n * d, "abs={abs}");
                for j in 0..n {
                    let tag = (1000 * layer + start + j) as f32;
                    assert_eq!(&rows[j * d..(j + 1) * d], row(tag, d).as_slice(),
                        "layer={layer} abs={abs} j={j}");
                }
            }
        }
    }

    #[test]
    fn prefix_sharing_attaches_and_cow_unshares_on_wrap() {
        let (layers, d, cap, p) = (1usize, 2usize, 8usize, 4usize);
        let mut pool = PagePool::new(layers, d, p, 8);
        let prompt: Vec<u32> = (0..6).collect(); // one full chunk + 2 tail
        // Writer admits, prefills, publishes.
        let SeqAdmit::Ready(mut w) = pool.admit_seq("t", &prompt, 2, cap, true) else {
            panic!("writer admit failed")
        };
        assert_eq!(w.pos(), 0);
        grow(&mut pool, &mut w, layers, d, 0, prompt.len());
        pool.publish_ready(&mut w);
        let base_in_use = pool.stats().in_use;
        // Attacher with the same prompt: hits the ready chunk, starts at
        // pos 4, and the pool grows by its private tail only.
        let SeqAdmit::Ready(mut a) = pool.admit_seq("t", &prompt, 2, cap, true) else {
            panic!("attacher admit failed")
        };
        assert_eq!(a.pos(), p, "attacher starts after the shared chunk");
        assert_eq!(pool.stats().shared_attached, 1);
        grow(&mut pool, &mut a, layers, d, a.pos(), prompt.len());
        // Shared page is one page, not two.
        assert_eq!(pool.stats().in_use, base_in_use + 1);
        // Shared rows read back bitwise from the attacher's walk.
        let (k, _v) = a.window_segments(0, 3).next().unwrap();
        assert_eq!(&k[0..d], row(1000.0 * 0.0, d).as_slice());
        // Wrap: position 8 lands back on the shared page 0 → CoW copy.
        let before = pool.stats().in_use;
        grow(&mut pool, &mut a, layers, d, prompt.len(), cap + 1);
        assert_eq!(pool.stats().in_use, before + 1, "un-share allocated one copy");
        // Writer's view of position 0..4 is untouched by the attacher's wrap.
        let (kw, _) = w.window_segments(0, 3).next().unwrap();
        assert_eq!(&kw[0..d], row(0.0, d).as_slice());
        // Releases drain everything back.
        pool.release_seq(&mut a);
        pool.release_seq(&mut w);
        assert_eq!(pool.stats().in_use, 0);
        assert_eq!(pool.free_pages(), pool.total_pages());
        assert_eq!(pool.available(), pool.total_pages());
    }

    #[test]
    fn same_pass_match_defers_then_attaches() {
        let (layers, d, cap, p) = (1usize, 2usize, 8usize, 4usize);
        let mut pool = PagePool::new(layers, d, p, 8);
        let prompt: Vec<u32> = (10..16).collect();
        let SeqAdmit::Ready(mut w) = pool.admit_seq("t", &prompt, 1, cap, true) else {
            panic!("writer admit failed")
        };
        // Second request in the same pass: the chunk is pending → Defer.
        assert!(matches!(pool.admit_seq("t", &prompt, 1, cap, true), SeqAdmit::Defer));
        // Forced (no progress): proceeds privately, no duplicate node.
        let SeqAdmit::Ready(mut forced) = pool.admit_seq("t", &prompt, 1, cap, false) else {
            panic!("forced admit failed")
        };
        assert_eq!(forced.pos(), 0, "forced path prefills privately");
        pool.release_seq(&mut forced);
        // After the writer's prefill, the deferred request attaches.
        grow(&mut pool, &mut w, layers, d, 0, prompt.len());
        pool.publish_ready(&mut w);
        let SeqAdmit::Ready(mut att) = pool.admit_seq("t", &prompt, 1, cap, true) else {
            panic!("deferred attach failed")
        };
        assert_eq!(att.pos(), p);
        // A different task never matches.
        let SeqAdmit::Ready(mut other) = pool.admit_seq("u", &prompt, 1, cap, true) else {
            panic!("other-task admit failed")
        };
        assert_eq!(other.pos(), 0);
        pool.release_seq(&mut att);
        pool.release_seq(&mut other);
        pool.release_seq(&mut w);
        assert_eq!(pool.stats().in_use, 0);
    }

    #[test]
    fn admission_rejects_never_fits_and_waits_on_pressure() {
        let (layers, d, cap, p) = (1usize, 2usize, 64usize, 4usize);
        let mut pool = PagePool::new(layers, d, p, 4);
        // 40 tokens → 10 pages > 4 total: Never.
        assert!(matches!(
            pool.admit_seq("t", &(0..32u32).collect::<Vec<_>>(), 8, cap, true),
            SeqAdmit::Never { need: 10, total: 4 }
        ));
        assert!(pool.never_fits(32, 8, cap).is_some());
        assert!(pool.never_fits(8, 4, cap).is_none());
        // First request reserves 3 pages; second (needing 3) must wait.
        let SeqAdmit::Ready(mut a) = pool.admit_seq("t", &[1, 2, 3], 6, cap, true) else {
            panic!("admit failed")
        };
        assert!(matches!(
            pool.admit_seq("t", &[4, 5, 6], 6, cap, true),
            SeqAdmit::NoPages { .. }
        ));
        pool.release_seq(&mut a);
        let SeqAdmit::Ready(mut b) = pool.admit_seq("t", &[4, 5, 6], 6, cap, true) else {
            panic!("post-release admit failed")
        };
        pool.release_seq(&mut b);
    }

    #[test]
    fn page_recycle_stress_bounds_the_high_water_mark() {
        // Many short sequential requests: the pool must recycle pages
        // (and buffers) instead of growing — peak stays at one
        // request's footprint even after hundreds of requests.
        let (layers, d, cap, p) = (2usize, 3usize, 32usize, 4usize);
        let mut pool = PagePool::new(layers, d, p, 64);
        let mut rng = Pcg32::seeded(0xabc, 3);
        let mut max_single = 0usize;
        for i in 0..300 {
            let plen = 1 + (rng.next_u32() as usize) % 10;
            let new = 1 + (rng.next_u32() as usize) % 6;
            let prompt: Vec<u32> = (0..plen as u32).map(|t| t + i).collect();
            let SeqAdmit::Ready(mut c) = pool.admit_seq("t", &prompt, new, cap, true) else {
                panic!("admit {i} failed")
            };
            grow(&mut pool, &mut c, layers, d, c.pos(), plen + new);
            max_single = max_single.max(c.pages_mapped());
            pool.release_seq(&mut c);
            assert_eq!(pool.stats().in_use, 0, "request {i} leaked pages");
        }
        assert!(pool.stats().peak <= max_single,
            "peak {} exceeds one request's footprint {}", pool.stats().peak, max_single);
        assert_eq!(pool.free_pages(), pool.total_pages());
    }
}
