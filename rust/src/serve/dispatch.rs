//! Work-queue dispatcher for the engine pool: bounded per-task ingress
//! queues with typed backpressure, deadline-based load shedding, and
//! task-affine batch handout.
//!
//! The dispatcher is the admission-control half of [`super::pool`]: it
//! owns everything that happens to a request *before* an engine sees it.
//! Workers call [`Dispatcher::next_batch`] in a loop; clients call
//! [`Dispatcher::submit`] from any thread.
//!
//! Policy, in dequeue order:
//!
//! 1. **Backpressure at submit.** Each task has a bounded FIFO queue
//!    (`queue_cap`); a submit that finds the task's queue full is
//!    rejected immediately with [`ServeError::Overloaded`] — it never
//!    queues, nothing is decoded, and the client is told the depth it
//!    hit. The bound is per task so one flooded task cannot starve the
//!    admission of others.
//! 2. **Deadline shedding at dispatch.** If `deadline_ms > 0`, requests
//!    that sat queued past the deadline are dropped when a worker next
//!    asks for work, each replied with [`ServeError::DeadlineExceeded`]
//!    — decode steps are never spent on an answer nobody is still
//!    waiting for. Per-queue FIFO order means expiry is checked at the
//!    queue heads only (the head is always the oldest).
//! 3. **Task-affine pick.** A PEQA task switch is cheap (a kilobyte
//!    scale swap) but not free; the dispatcher keeps a worker on its
//!    current task while that task has queued work, up to
//!    `affinity_burst` consecutive batches taken while an *older*
//!    request of another task waits (each such batch increments
//!    [`ServeMetrics::swaps_avoided`] — it is a swap the policy dodged).
//!    When the burst is spent, or the worker's task has no work, the
//!    pick falls back to the task whose queue head arrived earliest
//!    (global FIFO), which resets the burst. Staying on the current
//!    task while *no* other task waits costs nothing and accrues no
//!    burst debt.
//!
//! Shutdown is drain-then-exit: [`Dispatcher::close`] stops new
//! submits, but `next_batch` keeps handing out queued work until the
//! queues are empty and only then returns `None`.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::SyncSender;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use super::kvpage::DEFAULT_PAGE_TOKENS;
use super::types::{ServeError, ServeMetrics, StreamEvent};
use crate::util::sync::{lock_clean, wait_clean};

/// Admission-control knobs of the engine pool.
#[derive(Clone, Copy, Debug)]
pub struct DispatchConfig {
    /// Per-task ingress queue bound; a submit past it is rejected with
    /// [`ServeError::Overloaded`]. `0` means unbounded.
    pub queue_cap: usize,
    /// Requests queued longer than this are shed at dispatch with
    /// [`ServeError::DeadlineExceeded`]. `0` disables shedding.
    pub deadline_ms: u64,
    /// Max consecutive batches a worker stays on its current task while
    /// an older request of another task waits. `0` is plain global
    /// FIFO (every cross-task arrival forces a swap).
    pub affinity_burst: usize,
    /// Per-sequence KV window of the pool's workers; prompts longer
    /// than this are rejected at submit with
    /// [`ServeError::PromptTooLong`] instead of queueing toward a
    /// worker-side failure. `0` disables the gate.
    pub max_prompt: usize,
    /// Per-worker paged-KV pool size (pages); requests that could never
    /// fit it are rejected at submit with [`ServeError::KvExhausted`].
    /// `0` means the workers serve ring buffers — no page gate.
    pub kv_pages: usize,
    /// Tokens per KV page (the feasibility gate's unit; only read when
    /// `kv_pages > 0`).
    pub page_tokens: usize,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        DispatchConfig {
            queue_cap: 64,
            deadline_ms: 0,
            affinity_burst: 4,
            max_prompt: 0,
            kv_pages: 0,
            page_tokens: DEFAULT_PAGE_TOKENS,
        }
    }
}

/// One admitted pool request, handed from the dispatcher to a worker.
pub struct PoolRequest {
    /// Pool-wide monotonic id (assigned at submit, in arrival order —
    /// the FIFO key).
    pub id: u64,
    pub task: String,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub stop: u32,
    /// When the request entered the ingress queue; workers thread this
    /// through [`Scheduler::submit_queued_at`](super::scheduler::Scheduler::submit_queued_at)
    /// so TTFT and latency cover dispatcher wait.
    pub submitted: Instant,
    /// Reply channel: [`StreamEvent::Token`]s while decoding (streaming
    /// requests only), then exactly one terminal
    /// [`StreamEvent::Done`] / [`StreamEvent::Error`].
    pub reply: SyncSender<StreamEvent>,
    /// Whether the decode loop should stream accepted tokens into
    /// `reply` (non-streaming submits only want the terminal event).
    pub stream: bool,
}

struct State {
    /// Per-task FIFO queues; `PoolRequest::id` preserves global arrival
    /// order across them.
    queues: HashMap<String, VecDeque<PoolRequest>>,
    /// Total queued across all tasks.
    queued: usize,
    next_id: u64,
    open: bool,
    queue_depth_max: usize,
    shed_count: usize,
    swaps_avoided: usize,
    kv_exhausted: usize,
}

/// Shared work queue: `Mutex<State>` + condvar. Cheap to share — one
/// per pool, touched only at request granularity (never per token).
pub struct Dispatcher {
    cfg: DispatchConfig,
    state: Mutex<State>,
    ready: Condvar,
}

impl Dispatcher {
    pub fn new(cfg: DispatchConfig) -> Dispatcher {
        Dispatcher {
            cfg,
            state: Mutex::new(State {
                queues: HashMap::new(),
                queued: 0,
                next_id: 1,
                open: true,
                queue_depth_max: 0,
                shed_count: 0,
                swaps_avoided: 0,
                kv_exhausted: 0,
            }),
            ready: Condvar::new(),
        }
    }

    pub fn config(&self) -> &DispatchConfig {
        &self.cfg
    }

    /// Enqueue a request, or reject it right here: `Overloaded` when the
    /// task's bounded queue is full, `Failed` after [`Self::close`].
    /// Rejected requests never queue and never touch an engine.
    pub fn submit(
        &self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
        reply: SyncSender<StreamEvent>,
        stream: bool,
    ) -> Result<u64, ServeError> {
        // lock_clean: a worker that panicked while merging state must
        // not turn every later submit into a poison panic — admission
        // keeps answering (typed) on whatever state remains.
        let mut st = lock_clean(&self.state);
        if !st.open {
            return Err(ServeError::Failed("engine pool is shut down".into()));
        }
        // Feasibility gates before load gates: a request no worker could
        // ever serve is rejected typed, regardless of queue depth.
        if self.cfg.max_prompt > 0 && prompt.len() > self.cfg.max_prompt {
            return Err(ServeError::PromptTooLong { len: prompt.len(), cap: self.cfg.max_prompt });
        }
        if self.cfg.kv_pages > 0 {
            let p = self.cfg.page_tokens.max(1);
            let mut need = (prompt.len() + max_new).div_ceil(p);
            if self.cfg.max_prompt > 0 {
                // The ring overwrites in place past the window, so a
                // sequence never maps more pages than the window spans.
                need = need.min(self.cfg.max_prompt.div_ceil(p));
            }
            if need > self.cfg.kv_pages {
                st.kv_exhausted += 1;
                return Err(ServeError::KvExhausted {
                    task: task.to_string(),
                    need,
                    total: self.cfg.kv_pages,
                });
            }
        }
        let depth = st.queues.get(task).map_or(0, VecDeque::len);
        if self.cfg.queue_cap > 0 && depth >= self.cfg.queue_cap {
            st.shed_count += 1;
            return Err(ServeError::Overloaded {
                task: task.to_string(),
                depth,
                cap: self.cfg.queue_cap,
            });
        }
        let id = st.next_id;
        st.next_id += 1;
        // peqa-lint: allow(nondeterminism-sources) -- queue wait is the
        // measured quantity here: deadline shedding and TTFT both key off
        // this wall-clock stamp; it never reaches decoded output.
        st.queues.entry(task.to_string()).or_default().push_back(PoolRequest {
            id,
            task: task.to_string(),
            prompt,
            max_new,
            stop,
            submitted: Instant::now(),
            reply,
            stream,
        });
        st.queued += 1;
        st.queue_depth_max = st.queue_depth_max.max(st.queued);
        drop(st);
        self.ready.notify_one();
        Ok(id)
    }

    /// Block until work is available (or the dispatcher is closed and
    /// drained — then `None`), shed expired requests, and hand out up to
    /// `max_batch` requests of one task.
    ///
    /// `current_task` is the task the calling worker's engine currently
    /// has applied; `affinity_run` is that worker's consecutive-batch
    /// counter, owned by the worker and threaded back in unchanged so
    /// the dispatcher stays stateless about workers.
    pub fn next_batch(
        &self,
        current_task: Option<&str>,
        affinity_run: &mut usize,
        max_batch: usize,
    ) -> Option<(String, Vec<PoolRequest>)> {
        let mut st = lock_clean(&self.state);
        // Wait until some queue has a live head (shedding first). Keyed
        // on the queues themselves rather than the `queued` counter, so
        // a bookkeeping bug can never manifest as a panic here.
        let oldest = loop {
            self.shed_expired(&mut st);
            // Global FIFO head: the task whose front request arrived
            // first.
            let head = st
                .queues
                .iter()
                .filter_map(|(t, q)| q.front().map(|r| (r.id, t.clone())))
                .min_by_key(|(id, _)| *id);
            if let Some(oldest) = head {
                break oldest;
            }
            if !st.open {
                return None;
            }
            st = wait_clean(&self.ready, st);
        };
        let pick = match current_task {
            Some(cur) if st.queues.get(cur).is_some_and(|q| !q.is_empty()) => {
                if oldest.1 == cur {
                    // Current task IS the FIFO head — plain FIFO pick,
                    // no one is being kept waiting, burst debt resets.
                    *affinity_run = 0;
                    cur.to_string()
                } else if *affinity_run < self.cfg.affinity_burst {
                    // Affinity: stick with the applied task although an
                    // older other-task request waits — one scale swap
                    // avoided, one unit of burst debt accrued.
                    *affinity_run += 1;
                    st.swaps_avoided += 1;
                    cur.to_string()
                } else {
                    // Burst spent: fairness wins, switch to the oldest.
                    *affinity_run = 0;
                    oldest.1
                }
            }
            _ => {
                *affinity_run = 0;
                oldest.1
            }
        };
        // `pick` always names a non-empty queue (both arms checked), but
        // route the impossible case through `?` rather than a panic —
        // a worker thread must never die on dispatcher bookkeeping.
        let q = st.queues.get_mut(&pick)?;
        let n = max_batch.max(1).min(q.len());
        let batch: Vec<PoolRequest> = q.drain(..n).collect();
        st.queued -= n;
        Some((pick, batch))
    }

    /// Drop queue-head requests older than the deadline, replying
    /// `DeadlineExceeded` to each. FIFO per queue means heads are the
    /// oldest — once a head is fresh, the rest of that queue is too.
    fn shed_expired(&self, st: &mut State) {
        if self.cfg.deadline_ms == 0 {
            return;
        }
        let State { queues, queued, shed_count, .. } = st;
        for q in queues.values_mut() {
            loop {
                let Some(head) = q.front() else { break };
                let waited_ms = head.submitted.elapsed().as_millis() as u64;
                if waited_ms <= self.cfg.deadline_ms {
                    break;
                }
                let Some(r) = q.pop_front() else { break };
                *queued -= 1;
                *shed_count += 1;
                // try_send, because the dispatcher state lock is held
                // here: a never-dispatched request's reply channel
                // (cap >= 1) is provably empty, so this only fails when
                // the client already hung up — nothing to tell them.
                let _ = r.reply.try_send(StreamEvent::Error(ServeError::DeadlineExceeded {
                    task: r.task,
                    waited_ms,
                    deadline_ms: self.cfg.deadline_ms,
                }));
            }
        }
    }

    /// Stop accepting submits and wake every worker. Queued work still
    /// drains: workers keep getting batches until the queues are empty,
    /// then [`Self::next_batch`] returns `None` and they exit.
    pub fn close(&self) {
        let mut st = lock_clean(&self.state);
        st.open = false;
        drop(st);
        self.ready.notify_all();
    }

    /// Total requests queued (not yet handed to a worker).
    pub fn pending(&self) -> usize {
        lock_clean(&self.state).queued
    }

    /// Snapshot of the admission counters as a [`ServeMetrics`] block —
    /// only the dispatcher-owned fields are set, ready to be
    /// [`ServeMetrics::merge`]d with the per-worker scheduler metrics.
    pub fn admission_metrics(&self) -> ServeMetrics {
        let st = lock_clean(&self.state);
        ServeMetrics {
            queue_depth_max: st.queue_depth_max,
            shed_count: st.shed_count,
            swaps_avoided: st.swaps_avoided,
            kv_exhausted_count: st.kv_exhausted,
            ..ServeMetrics::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::{sync_channel, Receiver};
    use std::time::Duration;

    fn chan() -> (SyncSender<StreamEvent>, Receiver<StreamEvent>) {
        sync_channel(8)
    }

    #[test]
    fn bounded_ingress_rejects_past_cap_with_typed_error() {
        let d = Dispatcher::new(DispatchConfig { queue_cap: 2, ..DispatchConfig::default() });
        let (tx, _rx) = chan();
        d.submit("a", vec![1], 4, u32::MAX, tx.clone(), false).unwrap();
        d.submit("a", vec![2], 4, u32::MAX, tx.clone(), false).unwrap();
        let err = d.submit("a", vec![3], 4, u32::MAX, tx.clone(), false).unwrap_err();
        assert_eq!(err, ServeError::Overloaded { task: "a".into(), depth: 2, cap: 2 });
        // The bound is per task: another task still admits.
        d.submit("b", vec![4], 4, u32::MAX, tx, false).unwrap();
        let m = d.admission_metrics();
        assert_eq!(m.shed_count, 1);
        assert_eq!(m.queue_depth_max, 3, "rejected request never counted as queued");
        assert_eq!(d.pending(), 3);
    }

    #[test]
    fn deadline_shed_drops_stale_requests_with_typed_reply() {
        let d = Dispatcher::new(DispatchConfig {
            queue_cap: 0,
            deadline_ms: 25,
            affinity_burst: 0,
            ..DispatchConfig::default()
        });
        let (tx_old, rx_old) = chan();
        d.submit("a", vec![1], 4, u32::MAX, tx_old, false).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let (tx_new, _rx_new) = chan();
        d.submit("a", vec![2], 4, u32::MAX, tx_new, false).unwrap();
        let mut run = 0;
        let (task, batch) = d.next_batch(None, &mut run, 8).unwrap();
        assert_eq!(task, "a");
        assert_eq!(batch.len(), 1, "stale request shed, fresh one dispatched");
        assert_eq!(batch[0].prompt, vec![2]);
        match rx_old.try_recv().unwrap() {
            StreamEvent::Error(ServeError::DeadlineExceeded { waited_ms, deadline_ms, task }) => {
                assert_eq!(task, "a");
                assert_eq!(deadline_ms, 25);
                assert!(waited_ms > deadline_ms, "{waited_ms} <= {deadline_ms}");
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(d.admission_metrics().shed_count, 1);
        assert_eq!(d.pending(), 0);
    }

    #[test]
    fn affinity_sticks_within_burst_then_yields_to_older_task() {
        let d = Dispatcher::new(DispatchConfig {
            queue_cap: 0,
            affinity_burst: 2,
            ..DispatchConfig::default()
        });
        let (tx, _rx) = chan();
        for (task, p) in [("a", 1), ("b", 2), ("a", 3), ("a", 4), ("a", 5), ("b", 6)] {
            d.submit(task, vec![p], 1, u32::MAX, tx.clone(), false).unwrap();
        }
        let mut run = 0usize;
        let mut cur: Option<String> = None;
        let mut order: Vec<(String, u32)> = Vec::new();
        for _ in 0..6 {
            let (task, batch) = d.next_batch(cur.as_deref(), &mut run, 1).unwrap();
            assert_eq!(batch.len(), 1);
            order.push((task.clone(), batch[0].prompt[0]));
            cur = Some(task);
        }
        // FIFO would serve a,b,a,a,a,b (3 swaps after the first apply);
        // affinity serves a,a,a,b,b,a (2 swaps), yielding to the older
        // task "b" exactly when the 2-batch burst is spent, and never
        // reordering within a task.
        let want: Vec<(String, u32)> = [("a", 1), ("a", 3), ("a", 4), ("b", 2), ("b", 6), ("a", 5)]
            .iter()
            .map(|(t, p)| (t.to_string(), *p))
            .collect();
        assert_eq!(order, want);
        assert_eq!(d.admission_metrics().swaps_avoided, 3);
        assert_eq!(d.pending(), 0);
    }

    #[test]
    fn infeasible_requests_are_rejected_typed_at_ingress() {
        let d = Dispatcher::new(DispatchConfig {
            max_prompt: 16,
            kv_pages: 3,
            page_tokens: 4,
            ..DispatchConfig::default()
        });
        let (tx, _rx) = chan();
        // Prompt beyond the worker window: typed reject, nothing queued.
        let err = d.submit("a", vec![0; 17], 1, u32::MAX, tx.clone(), false).unwrap_err();
        assert_eq!(err, ServeError::PromptTooLong { len: 17, cap: 16 });
        // 8 prompt + 8 new = 4 pages > 3 in the pool (window spans 4):
        // no worker could ever map it, so it is shed before queueing.
        let err = d.submit("a", vec![0; 8], 8, u32::MAX, tx.clone(), false).unwrap_err();
        assert_eq!(err, ServeError::KvExhausted { task: "a".into(), need: 4, total: 3 });
        assert_eq!(d.admission_metrics().kv_exhausted_count, 1);
        // Within budget (8 + 4 = 3 pages): admitted.
        d.submit("a", vec![0; 8], 4, u32::MAX, tx, false).unwrap();
        assert_eq!(d.pending(), 1);
        assert_eq!(d.admission_metrics().shed_count, 0, "feasibility rejects are not load sheds");
    }

    #[test]
    fn close_drains_queued_work_then_returns_none() {
        let d = Dispatcher::new(DispatchConfig::default());
        let (tx, _rx) = chan();
        d.submit("a", vec![1], 1, u32::MAX, tx.clone(), false).unwrap();
        d.submit("b", vec![2], 1, u32::MAX, tx.clone(), false).unwrap();
        d.close();
        let mut run = 0;
        assert!(d.next_batch(None, &mut run, 1).is_some());
        assert!(d.next_batch(None, &mut run, 1).is_some());
        assert!(d.next_batch(None, &mut run, 1).is_none(), "drained + closed = exit");
        let err = d.submit("a", vec![3], 1, u32::MAX, tx, false).unwrap_err();
        assert!(matches!(err, ServeError::Failed(_)), "{err}");
    }

    #[test]
    fn next_batch_blocks_until_work_arrives() {
        let d = std::sync::Arc::new(Dispatcher::new(DispatchConfig::default()));
        let d2 = d.clone();
        let (tx, _rx) = chan();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            d2.submit("a", vec![7], 1, u32::MAX, tx, false).unwrap();
        });
        let mut run = 0;
        let (task, batch) = d.next_batch(None, &mut run, 4).unwrap();
        assert_eq!(task, "a");
        assert_eq!(batch[0].prompt, vec![7]);
    }
}
