//! Multi-task continuous-batching scheduler over the host decode engine.
//!
//! The serving loop the paper's Table 1 sketches, realized on the host
//! path: many tasks share one packed integer model; a task switch moves
//! only the f32 scale/zero tensors of the adapter-covered projections
//! ([`Engine::apply_adapter`] — codes never move) and its wall time is
//! recorded into [`ServeMetrics::swap_times_s`].
//!
//! Scheduling policy:
//! * Requests queue FIFO; the task of the queue head selects the next
//!   adapter. To minimize swaps the scheduler then drains *every* queued
//!   request of that task before switching again (task-greedy).
//! * Within a task, decoding is **continuous batching**: up to
//!   `max_batch` sequences advance together one token per step, and the
//!   moment one finishes, the next queued same-task request is admitted
//!   (prefilled) into the freed slot — the batch never drains to empty
//!   between requests.
//! * With [`Sampling::Greedy`] the generated tokens of every request are
//!   bit-identical regardless of `max_batch` and of the engine's worker
//!   thread count (the engine's per-sequence math is batch-independent);
//!   top-k sampling is deterministic given the scheduler seed but its
//!   draw order depends on batch composition.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::engine::{sample, Engine, Sampling};
use super::kvcache::KvCache;
use super::types::{AdapterStore, BatcherConfig, GenRequest, GenResponse, ServeMetrics};
use crate::util::Pcg32;

/// Scheduler knobs beyond the shared [`BatcherConfig`].
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    pub max_batch: usize,
    /// Per-sequence KV-cache capacity (attention window); sequences
    /// longer than this degrade to sliding-window attention.
    pub window: usize,
    pub sampling: Sampling,
    /// Seed of the top-k sampling stream.
    pub seed: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: BatcherConfig::default().max_batch,
            window: 256,
            sampling: Sampling::Greedy,
            seed: 0,
        }
    }
}

struct Slot {
    req: GenRequest,
    submitted: Instant,
    started: Instant,
    cache: KvCache,
    /// The token to feed at the next decode step (last sampled).
    next_token: u32,
    out: Vec<u32>,
}

/// Multi-task serving loop: queue + scale-swap + continuous batching.
pub struct Scheduler {
    engine: Engine,
    adapters: AdapterStore,
    cfg: SchedulerConfig,
    current_task: Option<String>,
    queue: VecDeque<(GenRequest, Instant)>,
    next_id: u64,
    rng: Pcg32,
    /// Reset KV caches of finished requests, reused by later admits so
    /// steady-state serving stops allocating window-sized buffers.
    spare_caches: Vec<KvCache>,
    pub metrics: ServeMetrics,
}

impl Scheduler {
    pub fn new(engine: Engine, adapters: AdapterStore, cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            engine,
            adapters,
            cfg,
            current_task: None,
            queue: VecDeque::new(),
            next_id: 1,
            rng: Pcg32::seeded(cfg.seed, 0x5c4ed),
            spare_caches: Vec::new(),
            metrics: ServeMetrics::default(),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn tasks(&self) -> Vec<&str> {
        self.adapters.tasks()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn submit(&mut self, task: &str, prompt: Vec<u32>, max_new: usize, stop: u32) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((
            GenRequest { id, task: task.to_string(), prompt, max_new, stop },
            Instant::now(),
        ));
        id
    }

    /// Switch the served task by scale swap; returns the swap wall time
    /// (0 and unrecorded when the task is already current).
    fn switch_task(&mut self, task: &str) -> Result<f64> {
        if self.current_task.as_deref() == Some(task) {
            return Ok(0.0);
        }
        let t0 = Instant::now();
        // The measured swap is exactly the adapter bytes moved once:
        // apply_adapter clones each s/z tensor into the packed matrices.
        let adapter = self
            .adapters
            .get(task)
            .ok_or_else(|| anyhow!("no adapter registered for task '{task}'"))?;
        self.engine.apply_adapter(adapter)?;
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.swap_times_s.push(dt);
        self.current_task = Some(task.to_string());
        Ok(dt)
    }

    /// Drain the queue; returns responses in completion order.
    pub fn run_until_idle(&mut self) -> Result<Vec<GenResponse>> {
        let wall0 = Instant::now();
        let mut responses = Vec::new();
        while let Some(task) = self.queue.front().map(|(r, _)| r.task.clone()) {
            self.switch_task(&task)?;
            let mut active: Vec<Slot> = Vec::new();
            loop {
                self.admit(&task, &mut active, &mut responses)?;
                if active.is_empty() {
                    break;
                }
                // One synchronized decode step over the live slots.
                let tokens: Vec<u32> = active.iter().map(|s| s.next_token).collect();
                {
                    let mut caches: Vec<&mut KvCache> =
                        active.iter_mut().map(|s| &mut s.cache).collect();
                    let logits = self.engine.decode_batch(&tokens, &mut caches)?;
                    drop(caches);
                    self.metrics.decode_steps += 1;
                    let vocab = self.engine.geom().vocab;
                    let mut i = 0;
                    while i < active.len() {
                        let next =
                            sample(&logits[i * vocab..(i + 1) * vocab], self.cfg.sampling, &mut self.rng);
                        let slot = &mut active[i];
                        let mut done = false;
                        if next == slot.req.stop {
                            // Stop id never reaches the output tokens.
                            done = true;
                        } else {
                            slot.out.push(next);
                            slot.next_token = next;
                            if slot.out.len() >= slot.req.max_new {
                                done = true;
                            }
                        }
                        if done {
                            let finished = active.swap_remove(i);
                            responses.push(self.finish_slot(finished));
                        } else {
                            i += 1;
                        }
                    }
                }
            }
        }
        self.metrics.wall_s += wall0.elapsed().as_secs_f64();
        Ok(responses)
    }

    /// Pull queued `task` requests into free batch slots, prefilling each
    /// prompt. Degenerate requests (empty prompt, `max_new == 0`, or a
    /// stop token predicted straight from the prompt) complete here.
    fn admit(
        &mut self,
        task: &str,
        active: &mut Vec<Slot>,
        responses: &mut Vec<GenResponse>,
    ) -> Result<()> {
        while active.len() < self.cfg.max_batch.max(1) {
            let Some(idx) = self.queue.iter().position(|(r, _)| r.task == task) else {
                break;
            };
            let (req, submitted) = self.queue.remove(idx).expect("position is in range");
            let started = Instant::now();
            if req.prompt.is_empty() || req.max_new == 0 {
                // Degenerate request: completes without touching the engine.
                let resp = self.finish(req, submitted, started, Vec::new());
                responses.push(resp);
                continue;
            }
            let mut cache = self
                .spare_caches
                .pop()
                .unwrap_or_else(|| self.engine.new_cache(self.cfg.window.max(1)));
            let logits = self.engine.prefill(&req.prompt, &mut cache)?;
            let first = sample(&logits, self.cfg.sampling, &mut self.rng);
            let mut slot = Slot { req, submitted, started, cache, next_token: first, out: Vec::new() };
            if first == slot.req.stop {
                responses.push(self.finish_slot(slot));
                continue;
            }
            slot.out.push(first);
            if slot.out.len() >= slot.req.max_new {
                responses.push(self.finish_slot(slot));
                continue;
            }
            active.push(slot);
        }
        Ok(())
    }

    fn finish_slot(&mut self, slot: Slot) -> GenResponse {
        let Slot { req, submitted, started, mut cache, out, .. } = slot;
        // Recycle the window-sized allocation for the next admit.
        if cache.capacity() == self.cfg.window.max(1) {
            cache.reset();
            self.spare_caches.push(cache);
        }
        self.finish(req, submitted, started, out)
    }

    fn finish(
        &mut self,
        req: GenRequest,
        submitted: Instant,
        started: Instant,
        out: Vec<u32>,
    ) -> GenResponse {
        let queue_s = (started - submitted).as_secs_f64();
        let latency_s = submitted.elapsed().as_secs_f64();
        self.metrics.completed += 1;
        self.metrics.generated_tokens += out.len();
        self.metrics.latencies_s.push(latency_s);
        self.metrics.queue_s.push(queue_s);
        GenResponse { id: req.id, task: req.task, tokens: out, queue_s, latency_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{synth_adapters, synth_packed};
    use crate::serve::engine::ModelGeom;

    fn tiny() -> (Engine, AdapterStore) {
        let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
        let (pm, base_q) = synth_packed(&geom, 4, None, 3).unwrap();
        let engine = Engine::from_packed(pm, geom, 2).unwrap();
        let adapters = synth_adapters(&base_q, &["a", "b", "c"], 5);
        (engine, adapters)
    }

    #[test]
    fn drains_mixed_tasks_with_scale_swaps() {
        let (engine, adapters) = tiny();
        let mut sched = Scheduler::new(engine, adapters, SchedulerConfig::default());
        for i in 0..9u32 {
            let task = ["a", "b", "c"][(i % 3) as usize];
            sched.submit(task, vec![1 + i, 2, 3], 5, u32::MAX);
        }
        let responses = sched.run_until_idle().unwrap();
        assert_eq!(responses.len(), 9);
        assert_eq!(sched.metrics.completed, 9);
        assert_eq!(sched.metrics.generated_tokens, 9 * 5);
        // Task-greedy drain: one swap per distinct task.
        assert_eq!(sched.metrics.swap_times_s.len(), 3);
        assert_eq!(sched.pending(), 0);
        assert!(sched.metrics.wall_s > 0.0);
        assert!(sched.metrics.decode_steps > 0);
    }

    #[test]
    fn degenerate_requests_complete_without_decoding() {
        let (engine, adapters) = tiny();
        let mut sched = Scheduler::new(engine, adapters, SchedulerConfig::default());
        let id_empty = sched.submit("a", vec![], 5, u32::MAX);
        let id_zero = sched.submit("a", vec![1, 2], 0, u32::MAX);
        let responses = sched.run_until_idle().unwrap();
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert!(r.tokens.is_empty(), "id {}", r.id);
            assert!([id_empty, id_zero].contains(&r.id));
        }
        assert_eq!(sched.metrics.decode_steps, 0);
    }

    #[test]
    fn unknown_task_is_an_error() {
        let (engine, adapters) = tiny();
        let mut sched = Scheduler::new(engine, adapters, SchedulerConfig::default());
        sched.submit("nope", vec![1], 3, u32::MAX);
        assert!(sched.run_until_idle().is_err());
    }
}
