//! Multi-task continuous-batching scheduler over the host decode engine.
//!
//! The serving loop the paper's Table 1 sketches, realized on the host
//! path: many tasks share one packed integer model; a task switch moves
//! only the f32 scale/zero tensors of the adapter-covered projections
//! ([`Engine::apply_adapter`] — codes never move, uncovered projections
//! revert to the base scales) and its wall time is recorded into
//! [`ServeMetrics::swap_times_s`].
//!
//! Scheduling policy:
//! * Requests queue FIFO. The queue is **indexed per task** (one
//!   `VecDeque` per task name plus a global arrival sequence number), so
//!   admitting into a freed slot pops the next same-task request in O(1)
//!   instead of re-scanning the whole queue per slot; the task whose
//!   front request arrived earliest selects the next adapter. To
//!   minimize swaps the scheduler then drains *every* queued request of
//!   that task before switching again (task-greedy).
//! * Admission is **cross-request prefill batched**: all prompts staffed
//!   into free slots in one admit pass go through a single
//!   [`Engine::prefill_batch`] call — one fused GEMM per projection over
//!   the concatenated prompt tokens of every admitted request, instead
//!   of one engine pass per prompt ([`ServeMetrics::prefill_batches`] /
//!   [`ServeMetrics::prefill_tokens`] record the grouping).
//! * Within a task, decoding is **continuous batching**: up to
//!   `max_batch` sequences advance together one token per step, and the
//!   moment slots free up, the next queued same-task requests are
//!   admitted (batch-prefilled) into them — the batch never drains to
//!   empty between requests.
//! * Finished requests return their KV cache to a **capacity-keyed spare
//!   pool**, so steady-state serving stops allocating window-sized
//!   buffers even across config changes (caches are recycled per
//!   capacity, never dropped for having the "wrong" one).
//! * With `kv_pages > 0` sequences draw fixed-size pages from a shared
//!   [`PagePool`] instead of owning full-window rings: admission
//!   reserves worst-case pages (page pressure leaves requests queued,
//!   infeasible ones get a typed [`ServeError::KvExhausted`] at
//!   submit), same-task requests attach already-written prompt-prefix
//!   pages copy-on-write and prefill only their tails, and finished
//!   sequences recycle pages through the pool's spare buffers. Decode
//!   output is bitwise identical to the ring backend.
//! * With [`Sampling::Greedy`] the generated tokens of every request are
//!   bit-identical regardless of `max_batch`, of prefill grouping, and
//!   of the engine's worker thread count (the engine's per-sequence math
//!   is batch-independent); top-k sampling is deterministic given the
//!   scheduler seed but its draw order depends on batch composition.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::SyncSender;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::engine::{sample, Engine, Sampling};
use super::kvcache::{KvCache, KvSeq};
use super::kvpage::{PagePool, SeqAdmit, DEFAULT_PAGE_TOKENS};
use super::types::{
    AdapterStore, BatcherConfig, GenRequest, GenResponse, ServeError, ServeMetrics, StreamEvent,
};
use crate::util::Pcg32;

/// Scheduler knobs beyond the shared [`BatcherConfig`].
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    pub max_batch: usize,
    /// Per-sequence KV-cache capacity (attention window); sequences
    /// longer than this degrade to sliding-window attention.
    pub window: usize,
    pub sampling: Sampling,
    /// Seed of the top-k sampling stream.
    pub seed: u64,
    /// Strict adapter coverage (`BatcherConfig::strict_coverage`):
    /// [`Scheduler::new`] rejects any registered adapter that does not
    /// cover every packed projection, instead of serving uncovered
    /// projections at base scales.
    pub strict_coverage: bool,
    /// Paged-KV pool size in pages (CLI `--kv-pages`). 0 serves every
    /// sequence from a full-window ring buffer (the bitwise oracle);
    /// > 0 serves from a shared [`PagePool`] with copy-on-write
    /// prompt-prefix sharing — generated tokens are bitwise identical
    /// either way.
    pub kv_pages: usize,
    /// Tokens per KV page (CLI `--page-tokens`; paged backend only).
    pub page_tokens: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        let batcher = BatcherConfig::default();
        SchedulerConfig {
            max_batch: batcher.max_batch,
            window: 256,
            sampling: Sampling::Greedy,
            seed: 0,
            strict_coverage: batcher.strict_coverage,
            kv_pages: 0,
            page_tokens: DEFAULT_PAGE_TOKENS,
        }
    }
}

struct Slot {
    req: GenRequest,
    submitted: Instant,
    started: Instant,
    cache: KvSeq,
    /// The token to feed at the next decode step (last sampled).
    next_token: u32,
    out: Vec<u32>,
    /// Streaming reply channel: every accepted token is sent the moment
    /// the decode loop accepts it (the stop id is never sent — it never
    /// reaches `out` either). `None` for non-streaming requests.
    sink: Option<SyncSender<StreamEvent>>,
    /// When the previous token was accepted (TTFT / inter-token gaps).
    last_accept: Option<Instant>,
}

/// One queued request. Arrival order is the (monotonic) `req.id`.
struct Queued {
    req: GenRequest,
    submitted: Instant,
    sink: Option<SyncSender<StreamEvent>>,
}

/// Multi-task serving loop: indexed queue + scale-swap + continuous
/// batching with cross-request prefill.
pub struct Scheduler {
    engine: Engine,
    adapters: AdapterStore,
    cfg: SchedulerConfig,
    current_task: Option<String>,
    /// Per-task FIFO queues; the monotonic request id preserves global
    /// arrival order, so head-of-line selection stays FIFO across tasks.
    queues: HashMap<String, VecDeque<Queued>>,
    queued: usize,
    next_id: u64,
    rng: Pcg32,
    /// Reset KV caches of finished requests keyed by capacity, reused by
    /// later admits so steady-state serving stops allocating
    /// window-sized buffers (ring backend; the paged backend recycles
    /// through the pool's page spares instead).
    spare_caches: HashMap<usize, Vec<KvCache>>,
    /// The paged-KV page pool (`cfg.kv_pages > 0`); `None` serves rings.
    pool: Option<PagePool>,
    pub metrics: ServeMetrics,
}

impl Scheduler {
    /// Build the serving loop. In strict-coverage mode
    /// (`cfg.strict_coverage`) every registered adapter is validated
    /// against the engine's packed projections up front
    /// ([`Engine::adapter_coverage_gaps`]) — a partial adapter is a
    /// registration error, never a silently-based task.
    pub fn new(engine: Engine, adapters: AdapterStore, cfg: SchedulerConfig) -> Result<Scheduler> {
        if cfg.strict_coverage {
            super::types::validate_coverage(&engine.model().prefixes(), &adapters)?;
        }
        let pool = if cfg.kv_pages > 0 {
            let g = engine.geom();
            Some(PagePool::new(g.n_layers, g.d_model, cfg.page_tokens.max(1), cfg.kv_pages))
        } else {
            None
        };
        Ok(Scheduler {
            engine,
            adapters,
            cfg,
            current_task: None,
            queues: HashMap::new(),
            queued: 0,
            next_id: 1,
            rng: Pcg32::seeded(cfg.seed, 0x5c4ed),
            spare_caches: HashMap::new(),
            pool,
            metrics: ServeMetrics::default(),
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn tasks(&self) -> Vec<&str> {
        self.adapters.tasks()
    }

    /// Whether an adapter is registered for `task` (the server wrapper
    /// rejects unknown tasks at submit time instead of poisoning the
    /// drain loop).
    pub fn has_task(&self, task: &str) -> bool {
        self.adapters.get(task).is_some()
    }

    pub fn pending(&self) -> usize {
        self.queued
    }

    /// Replace the registered adapter set in place (adapter hot-reload —
    /// the registry watcher's entry point). The incoming store is
    /// **always** validated through the strict-coverage path regardless
    /// of `cfg.strict_coverage`: a hot-reload that silently serves
    /// uncovered projections at base scales is a deployment hazard, not
    /// a convenience. On validation failure the current adapters keep
    /// serving, untouched. On success returns the new task count; the
    /// current-task marker is cleared so the next drain re-applies the
    /// (possibly re-trained) adapter instead of trusting stale scales
    /// already in the engine.
    pub fn reload_adapters(&mut self, adapters: AdapterStore) -> Result<usize> {
        super::types::validate_coverage(&self.engine.model().prefixes(), &adapters)?;
        let n = adapters.tasks().len();
        self.adapters = adapters;
        self.current_task = None;
        Ok(n)
    }

    /// Drop every queued (not-yet-admitted) request, returning how many
    /// were discarded. The server wrapper calls this after a drain error
    /// so clients whose requests were failed-by-error are not silently
    /// re-decoded for nobody on the next drain.
    pub fn clear_queue(&mut self) -> usize {
        let dropped = self.queued;
        self.queues.clear();
        self.queued = 0;
        dropped
    }

    pub fn submit(
        &mut self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
    ) -> Result<u64, ServeError> {
        self.submit_streaming(task, prompt, max_new, stop, None)
    }

    /// [`Self::submit`] with an optional streaming sink: every token the
    /// decode loop accepts for this request is also sent as
    /// [`StreamEvent::Token`] the moment it is accepted. The generated
    /// tokens are bitwise identical to a sink-less submit — streaming is
    /// an extra send at the acceptance site, never a different decode.
    /// A full sink blocks the decode loop (bounded-channel backpressure:
    /// a client that stops draining stalls its own batch); a dropped
    /// sink is ignored and generation completes normally.
    pub fn submit_streaming(
        &mut self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
        sink: Option<SyncSender<StreamEvent>>,
    ) -> Result<u64, ServeError> {
        // peqa-lint: allow(nondeterminism-sources) -- submission stamp:
        // queue_s / latency_s / TTFT all key off it; it never reaches
        // decoded output.
        self.submit_queued_at(task, prompt, max_new, stop, sink, Instant::now())
    }

    /// [`Self::submit_streaming`] with an explicit submission instant.
    /// The engine pool passes the moment the request entered its ingress
    /// queue, so `queue_s`, `latency_s` and TTFT cover dispatcher wait
    /// time too — not just the slice spent inside this scheduler.
    ///
    /// Typed rejects, both before anything queues or decodes:
    /// * [`ServeError::PromptTooLong`] — the prompt alone exceeds the
    ///   KV window, so decode would slide past the prompt's own tokens
    ///   before the first generated one (historically this was accepted
    ///   and silently served windowed-prompt generations).
    /// * [`ServeError::KvExhausted`] — paged backend only: the request
    ///   could never fit `--kv-pages` even with the pool entirely free.
    pub fn submit_queued_at(
        &mut self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
        sink: Option<SyncSender<StreamEvent>>,
        submitted: Instant,
    ) -> Result<u64, ServeError> {
        let window = self.cfg.window.max(1);
        if prompt.len() > window {
            return Err(ServeError::PromptTooLong { len: prompt.len(), cap: window });
        }
        if let Some(pool) = &self.pool {
            if let Some((need, total)) = pool.never_fits(prompt.len(), max_new, window) {
                self.metrics.kv_exhausted_count += 1;
                return Err(ServeError::KvExhausted { task: task.to_string(), need, total });
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queues.entry(task.to_string()).or_default().push_back(Queued {
            req: GenRequest { id, task: task.to_string(), prompt, max_new, stop },
            submitted,
            sink,
        });
        self.queued += 1;
        self.metrics.queue_depth_max = self.metrics.queue_depth_max.max(self.queued);
        Ok(id)
    }

    /// The task whose queue front arrived earliest (global FIFO head —
    /// ids are assigned in arrival order).
    fn head_task(&self) -> Option<String> {
        self.queues
            .iter()
            .filter_map(|(task, q)| q.front().map(|h| (h.req.id, task)))
            .min_by_key(|(id, _)| *id)
            .map(|(_, task)| task.clone())
    }

    /// Switch the served task by scale swap; returns the swap wall time
    /// (0 and unrecorded when the task is already current).
    fn switch_task(&mut self, task: &str) -> Result<f64> {
        if self.current_task.as_deref() == Some(task) {
            return Ok(0.0);
        }
        // peqa-lint: allow(nondeterminism-sources) -- the swap wall time
        // IS the reported measurement (paper Table 4); tokens are
        // unaffected.
        let t0 = Instant::now();
        // The measured swap is exactly the adapter bytes moved once:
        // apply_adapter clones each s/z tensor into the packed matrices
        // (plus base restores for projections the adapter leaves out).
        let adapter = self
            .adapters
            .get(task)
            .ok_or_else(|| anyhow!("no adapter registered for task '{task}'"))?;
        self.engine.apply_adapter(adapter)?;
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.swap_times_s.push(dt);
        self.current_task = Some(task.to_string());
        Ok(dt)
    }

    /// Drain the queue; returns responses in completion order.
    pub fn run_until_idle(&mut self) -> Result<Vec<GenResponse>> {
        // peqa-lint: allow(nondeterminism-sources) -- batch wall clock
        // for the throughput metric; decode order and tokens are
        // deterministic regardless.
        let wall0 = Instant::now();
        let mut responses = Vec::new();
        while let Some(task) = self.head_task() {
            self.switch_task(&task)?;
            let mut active: Vec<Slot> = Vec::new();
            loop {
                self.admit(&task, &mut active, &mut responses)?;
                if active.is_empty() {
                    break;
                }
                // One synchronized decode step over the live slots. Paged
                // sequences un-share / allocate their next position here,
                // on this thread, before the engine's worker threads
                // touch the caches (the CoW contract of serve::kvpage).
                if let Some(pool) = self.pool.as_mut() {
                    for slot in active.iter_mut() {
                        if let KvSeq::Paged(pc) = &mut slot.cache {
                            pc.prepare(pool, 1).map_err(|e| anyhow!("{e}"))?;
                        }
                    }
                }
                let tokens: Vec<u32> = active.iter().map(|s| s.next_token).collect();
                {
                    let mut caches: Vec<&mut KvSeq> =
                        active.iter_mut().map(|s| &mut s.cache).collect();
                    let logits = self.engine.decode_batch(&tokens, &mut caches)?;
                    drop(caches);
                    self.metrics.decode_steps += 1;
                    let vocab = self.engine.geom().vocab;
                    let mut i = 0;
                    while i < active.len() {
                        let next =
                            sample(&logits[i * vocab..(i + 1) * vocab], self.cfg.sampling, &mut self.rng);
                        let slot = &mut active[i];
                        let mut done = false;
                        if next == slot.req.stop {
                            // Stop id never reaches the output tokens.
                            done = true;
                        } else {
                            accept_token(slot, next, &mut self.metrics);
                            slot.next_token = next;
                            if slot.out.len() >= slot.req.max_new {
                                done = true;
                            }
                        }
                        if done {
                            let finished = active.swap_remove(i);
                            responses.push(self.finish_slot(finished));
                        } else {
                            i += 1;
                        }
                    }
                }
            }
        }
        self.metrics.wall_s += wall0.elapsed().as_secs_f64();
        // Harvest pool counters: the peak is a level (merge takes max),
        // the shared counter is drained as a delta so repeated drains
        // never double-count.
        if let Some(pool) = self.pool.as_mut() {
            self.metrics.kv_pages_peak = self.metrics.kv_pages_peak.max(pool.stats().peak);
            self.metrics.kv_pages_shared += pool.take_shared_count();
        }
        Ok(responses)
    }

    /// Put a popped request back at the front of its task queue (paged
    /// admission told us to wait: a pending same-pass prefix, or
    /// transient page pressure a finishing slot will relieve).
    fn requeue_front(&mut self, task: &str, q: Queued) {
        self.queues.entry(task.to_string()).or_default().push_front(q);
        self.queued += 1;
    }

    /// Pull queued `task` requests into free batch slots and prefill all
    /// their prompts through ONE [`Engine::prefill_batch`] call per admit
    /// pass (cross-request prefill batching). Degenerate requests (empty
    /// prompt, `max_new == 0`) complete here without touching the
    /// engine; requests whose first sampled token already stops them (or
    /// whose `max_new` is 1) complete at prefill and free their slot for
    /// the next pass of the loop.
    ///
    /// On the paged backend each staffing consults
    /// [`PagePool::admit_seq`]: a request whose prompt prefix was
    /// already written by an earlier same-task request attaches those
    /// pages copy-on-write and prefills only its tail; a prefix
    /// registered earlier in this very pass defers until that prefill
    /// publishes; page pressure leaves the request queued until
    /// finishing slots release pages.
    fn admit(
        &mut self,
        task: &str,
        active: &mut Vec<Slot>,
        responses: &mut Vec<GenResponse>,
    ) -> Result<()> {
        let mut allow_defer = true;
        loop {
            let cap = self.cfg.max_batch.max(1);
            // Staff every free slot from the per-task queue: O(1) pops
            // instead of an O(queue) scan per freed slot.
            let mut pending: Vec<Queued> = Vec::new();
            let mut caches: Vec<KvSeq> = Vec::new();
            let mut starts: Vec<Instant> = Vec::new();
            let mut deferred = false;
            while active.len() + pending.len() < cap {
                let Some(q) = self.queues.get_mut(task).and_then(VecDeque::pop_front) else {
                    break;
                };
                self.queued -= 1;
                // peqa-lint: allow(nondeterminism-sources) -- service
                // start stamp for queue/latency metrics only.
                let started = Instant::now();
                if q.req.prompt.is_empty() || q.req.max_new == 0 {
                    // Degenerate request: completes without the engine.
                    let resp = self.finish(q.req, q.submitted, started, Vec::new());
                    responses.push(resp);
                    continue;
                }
                let window = self.cfg.window.max(1);
                let staffed = match self.pool.as_mut() {
                    None => match self.spare_caches.get_mut(&window).and_then(Vec::pop) {
                        Some(c) => Some(KvSeq::Ring(c)),
                        None => Some(self.engine.new_cache(window)),
                    },
                    Some(pool) => {
                        match pool.admit_seq(task, &q.req.prompt, q.req.max_new, window, allow_defer)
                        {
                            SeqAdmit::Ready(mut pc) => {
                                // Grow the tail the prefill below will
                                // write; attached prefix pages stay
                                // shared and untouched.
                                let tail = q.req.prompt.len() - pc.pos();
                                pc.prepare(pool, tail).map_err(|e| anyhow!("{e}"))?;
                                Some(KvSeq::Paged(pc))
                            }
                            SeqAdmit::Defer => {
                                deferred = true;
                                None
                            }
                            SeqAdmit::NoPages { .. } => None,
                            SeqAdmit::Never { need, total } => {
                                // Unreachable through submit (the same
                                // never_fits gate runs there), but config
                                // drift must fail loudly, not spin here.
                                return Err(anyhow!(ServeError::KvExhausted {
                                    task: task.to_string(),
                                    need,
                                    total,
                                }));
                            }
                        }
                    }
                };
                let Some(cache) = staffed else {
                    self.requeue_front(task, q);
                    break;
                };
                pending.push(q);
                starts.push(started);
                caches.push(cache);
            }
            if pending.is_empty() {
                if deferred && active.is_empty() {
                    // Livelock guard: nothing is decoding and nothing was
                    // staffed, so no prefill in flight will ever publish
                    // the pending chunks — re-admit without deferral (the
                    // head request prefills its prompt privately).
                    allow_defer = false;
                    continue;
                }
                return Ok(());
            }
            allow_defer = true;
            // One fused prefill over every admitted prompt tail. Row i of
            // the returned logits is bitwise what a lone prefill of the
            // whole prompt i would produce (attached prefix pages hold
            // exactly the rows that lone prefill would have written), so
            // neither grouping nor sharing ever changes generations.
            let logits = {
                let prompts: Vec<&[u32]> = pending
                    .iter()
                    .zip(&caches)
                    .map(|(q, c)| &q.req.prompt[c.pos()..])
                    .collect();
                self.metrics.prefill_tokens += prompts.iter().map(|p| p.len()).sum::<usize>();
                let mut cache_refs: Vec<&mut KvSeq> = caches.iter_mut().collect();
                self.engine.prefill_batch(&prompts, &mut cache_refs)?
            };
            self.metrics.prefill_batches += 1;
            // Publish this pass's freshly-written prompt chunks so the
            // next staffing pass (and every later request) can attach
            // them instead of re-prefilling.
            if let Some(pool) = self.pool.as_mut() {
                for c in caches.iter_mut() {
                    if let KvSeq::Paged(pc) = c {
                        pool.publish_ready(pc);
                    }
                }
            }
            let vocab = self.engine.geom().vocab;
            for (i, ((q, started), cache)) in
                pending.into_iter().zip(starts).zip(caches).enumerate()
            {
                let first =
                    sample(&logits[i * vocab..(i + 1) * vocab], self.cfg.sampling, &mut self.rng);
                let mut slot = Slot {
                    req: q.req,
                    submitted: q.submitted,
                    started,
                    cache,
                    next_token: first,
                    out: Vec::new(),
                    sink: q.sink,
                    last_accept: None,
                };
                if first == slot.req.stop {
                    responses.push(self.finish_slot(slot));
                    continue;
                }
                accept_token(&mut slot, first, &mut self.metrics);
                if slot.out.len() >= slot.req.max_new {
                    responses.push(self.finish_slot(slot));
                    continue;
                }
                active.push(slot);
            }
            // Requests that completed at prefill freed capacity — loop to
            // staff those slots too before the first decode step.
        }
    }

    fn finish_slot(&mut self, slot: Slot) -> GenResponse {
        let Slot { req, submitted, started, cache, out, .. } = slot;
        match cache {
            KvSeq::Ring(mut c) => {
                // Recycle the window-sized allocation for a later admit.
                // Keyed by capacity so a cache sized under a different
                // window config is kept for same-capacity reuse instead
                // of being dropped.
                c.reset();
                self.spare_caches.entry(c.capacity()).or_default().push(c);
            }
            KvSeq::Paged(mut pc) => {
                // Page recycling: every page, reservation, and trie hold
                // goes back to the pool the moment the request finishes.
                if let Some(pool) = self.pool.as_mut() {
                    pool.release_seq(&mut pc);
                }
            }
        }
        self.finish(req, submitted, started, out)
    }

    fn finish(
        &mut self,
        req: GenRequest,
        submitted: Instant,
        started: Instant,
        out: Vec<u32>,
    ) -> GenResponse {
        let queue_s = (started - submitted).as_secs_f64();
        let latency_s = submitted.elapsed().as_secs_f64();
        self.metrics.completed += 1;
        self.metrics.generated_tokens += out.len();
        self.metrics.latencies_s.push(latency_s);
        self.metrics.queue_s.push(queue_s);
        GenResponse { id: req.id, task: req.task, tokens: out, queue_s, latency_s }
    }
}

/// Accept one generated token into a slot: record TTFT (first accepted
/// token, measured from submit) or the inter-token gap, append it to
/// the output, and feed the streaming sink if the request has one.
/// Metrics and the sink send are pure observers — the token path is
/// identical with or without them, which is what keeps streamed and
/// non-streamed generations bitwise equal.
fn accept_token(slot: &mut Slot, tok: u32, metrics: &mut ServeMetrics) {
    // peqa-lint: allow(nondeterminism-sources) -- TTFT / inter-token gap
    // measurement; a pure observer of the token path (doc above).
    let now = Instant::now();
    match slot.last_accept {
        None => metrics.ttft_s.push(now.duration_since(slot.submitted).as_secs_f64()),
        Some(prev) => metrics.inter_token_s.push(now.duration_since(prev).as_secs_f64()),
    }
    slot.last_accept = Some(now);
    slot.out.push(tok);
    if let Some(sink) = &slot.sink {
        // A dropped receiver (client went away) is not an error — the
        // request still completes; a full bounded channel blocks here,
        // so a client that stops draining backpressures its own batch.
        let _ = sink.send(StreamEvent::Token(tok));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{synth_adapters, synth_packed};
    use crate::serve::engine::ModelGeom;

    fn tiny() -> (Engine, AdapterStore) {
        let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
        let (pm, base_q) = synth_packed(&geom, 4, None, 3).unwrap();
        let engine = Engine::from_packed(pm, geom, 2).unwrap();
        let adapters = synth_adapters(&base_q, &["a", "b", "c"], 5);
        (engine, adapters)
    }

    #[test]
    fn drains_mixed_tasks_with_scale_swaps() {
        let (engine, adapters) = tiny();
        let mut sched = Scheduler::new(engine, adapters, SchedulerConfig::default()).unwrap();
        for i in 0..9u32 {
            let task = ["a", "b", "c"][(i % 3) as usize];
            sched.submit(task, vec![1 + i, 2, 3], 5, u32::MAX).unwrap();
        }
        let responses = sched.run_until_idle().unwrap();
        assert_eq!(responses.len(), 9);
        assert_eq!(sched.metrics.completed, 9);
        assert_eq!(sched.metrics.generated_tokens, 9 * 5);
        // Task-greedy drain: one swap per distinct task.
        assert_eq!(sched.metrics.swap_times_s.len(), 3);
        assert_eq!(sched.pending(), 0);
        assert!(sched.metrics.wall_s > 0.0);
        assert!(sched.metrics.decode_steps > 0);
        // Every prefill pass covered multiple same-task prompts at once.
        assert!(sched.metrics.prefill_batches <= 3, "{}", sched.metrics.prefill_batches);
        assert_eq!(sched.metrics.prefill_tokens, 9 * 3);
        // Latency instrumentation: one TTFT sample per request, one
        // inter-token gap per accepted token after the first.
        assert_eq!(sched.metrics.ttft_s.len(), 9);
        assert_eq!(sched.metrics.inter_token_s.len(), 9 * 4);
        assert_eq!(sched.metrics.queue_depth_max, 9);
        assert_eq!(sched.metrics.shed_count, 0);
    }

    #[test]
    fn streaming_sink_receives_exactly_the_response_tokens() {
        let (engine, adapters) = tiny();
        let mut sched = Scheduler::new(engine, adapters, SchedulerConfig::default()).unwrap();
        let (tx, rx) = std::sync::mpsc::sync_channel(64);
        let id = sched.submit_streaming("a", vec![1, 2, 3], 6, u32::MAX, Some(tx)).unwrap();
        sched.submit("b", vec![4, 5], 4, u32::MAX).unwrap();
        let responses = sched.run_until_idle().unwrap();
        let resp = responses.iter().find(|r| r.id == id).unwrap();
        let mut streamed = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Token(t) => streamed.push(t),
                other => panic!("scheduler only sends Token events, got {other:?}"),
            }
        }
        assert_eq!(streamed, resp.tokens, "stream must reassemble to the response bitwise");
        assert_eq!(streamed.len(), 6);
    }

    #[test]
    fn many_request_admission_is_indexed_and_recycles_caches() {
        let (engine, adapters) = tiny();
        let cfg = SchedulerConfig {
            max_batch: 4,
            window: 32,
            ..SchedulerConfig::default()
        };
        let mut sched = Scheduler::new(engine, adapters, cfg).unwrap();
        // 60 interleaved requests over 3 tasks: per-task pops must stay
        // O(1) (indexed queues) and FIFO head selection must still be
        // global-arrival order.
        for i in 0..60u32 {
            let task = ["a", "b", "c"][(i % 3) as usize];
            sched.submit(task, vec![1 + (i % 50), 2, 3], 3, u32::MAX).unwrap();
        }
        assert_eq!(sched.pending(), 60);
        let responses = sched.run_until_idle().unwrap();
        assert_eq!(responses.len(), 60);
        assert_eq!(sched.pending(), 0);
        assert_eq!(sched.metrics.completed, 60);
        assert_eq!(sched.metrics.generated_tokens, 60 * 3);
        // Task-greedy drain still groups by task: one swap each.
        assert_eq!(sched.metrics.swap_times_s.len(), 3);
        // Cross-request prefill: 20 same-task requests at max_batch 4 →
        // 5 admit batches per task, not one engine pass per request.
        assert_eq!(sched.metrics.prefill_batches, 15);
        assert_eq!(sched.metrics.prefill_tokens, 60 * 3);
        // Caches were recycled through the capacity-keyed pool: the
        // whole run never held more than one batch worth of caches.
        let spares: usize = sched.spare_caches.values().map(Vec::len).sum();
        assert!(spares <= 4, "spare caches grew to {spares}");
        assert!(sched.spare_caches.keys().all(|&c| c == 32));
    }

    #[test]
    fn oversized_prompt_is_rejected_at_submit() {
        let (engine, adapters) = tiny();
        let cfg = SchedulerConfig { window: 8, ..SchedulerConfig::default() };
        let mut sched = Scheduler::new(engine, adapters, cfg).unwrap();
        // Regression: a prompt longer than the KV window used to queue
        // and silently serve sliding-window generations of a prompt the
        // cache could never hold; now it is a typed submit-time reject.
        let err = sched.submit("a", (0..9).collect(), 4, u32::MAX).unwrap_err();
        assert!(matches!(err, ServeError::PromptTooLong { len: 9, cap: 8 }), "{err}");
        assert_eq!(sched.pending(), 0, "rejected request must never queue");
        // At the boundary the prompt is accepted and serves fully.
        sched.submit("a", (0..8).collect(), 2, u32::MAX).unwrap();
        assert_eq!(sched.run_until_idle().unwrap().len(), 1);
    }

    #[test]
    fn kv_exhausted_is_rejected_at_submit_with_typed_error() {
        let (engine, adapters) = tiny();
        let cfg = SchedulerConfig {
            window: 64,
            kv_pages: 2,
            page_tokens: 4,
            ..SchedulerConfig::default()
        };
        let mut sched = Scheduler::new(engine, adapters, cfg).unwrap();
        // 8 prompt + 4 new tokens need 3 pages; the pool holds 2 total.
        let err = sched.submit("a", (0..8).collect(), 4, u32::MAX).unwrap_err();
        assert!(matches!(err, ServeError::KvExhausted { need: 3, total: 2, .. }), "{err}");
        assert_eq!(sched.metrics.kv_exhausted_count, 1);
        assert_eq!(sched.pending(), 0);
        // A fitting request on the same pool still serves.
        sched.submit("a", vec![1, 2, 3], 4, u32::MAX).unwrap();
        assert_eq!(sched.run_until_idle().unwrap().len(), 1);
    }

    #[test]
    fn paged_backend_matches_ring_bitwise_and_shares_prefixes() {
        let (engine, adapters) = tiny();
        let ring_cfg = SchedulerConfig { max_batch: 4, window: 32, ..SchedulerConfig::default() };
        let mut ring = Scheduler::new(engine, adapters, ring_cfg).unwrap();
        let (engine, adapters) = tiny();
        let paged_cfg = SchedulerConfig {
            max_batch: 4,
            window: 32,
            kv_pages: 24,
            page_tokens: 4,
            ..SchedulerConfig::default()
        };
        let mut paged = Scheduler::new(engine, adapters, paged_cfg).unwrap();
        // Six same-task requests sharing an 8-token prefix (two full
        // pages) with distinct final tokens.
        let prefix: Vec<u32> = (1..9).collect();
        for i in 0..6u32 {
            let mut p = prefix.clone();
            p.push(40 + i);
            ring.submit("a", p.clone(), 6, u32::MAX).unwrap();
            paged.submit("a", p, 6, u32::MAX).unwrap();
        }
        let mut r = ring.run_until_idle().unwrap();
        let mut p = paged.run_until_idle().unwrap();
        r.sort_by_key(|x| x.id);
        p.sort_by_key(|x| x.id);
        assert_eq!(r.len(), 6);
        assert_eq!(p.len(), 6);
        for (a, b) in r.iter().zip(&p) {
            assert_eq!(a.tokens, b.tokens, "paged decode diverged from ring on id {}", a.id);
            assert_eq!(a.tokens.len(), 6);
        }
        // The memory claim: prefix pages were attached, not duplicated,
        // and the engine prefilled only the attachers' tails.
        assert!(paged.metrics.kv_pages_shared > 0, "no prefix pages were shared");
        assert!(paged.metrics.kv_pages_peak > 0);
        assert!(paged.metrics.kv_pages_peak <= 24);
        assert_eq!(ring.metrics.kv_pages_shared, 0);
        assert_eq!(ring.metrics.kv_pages_peak, 0);
        assert!(
            paged.metrics.prefill_tokens < ring.metrics.prefill_tokens,
            "sharing saved no prefill work: paged {} vs ring {}",
            paged.metrics.prefill_tokens,
            ring.metrics.prefill_tokens
        );
    }

    #[test]
    fn degenerate_requests_complete_without_decoding() {
        let (engine, adapters) = tiny();
        let mut sched = Scheduler::new(engine, adapters, SchedulerConfig::default()).unwrap();
        let id_empty = sched.submit("a", vec![], 5, u32::MAX).unwrap();
        let id_zero = sched.submit("a", vec![1, 2], 0, u32::MAX).unwrap();
        let responses = sched.run_until_idle().unwrap();
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert!(r.tokens.is_empty(), "id {}", r.id);
            assert!([id_empty, id_zero].contains(&r.id));
        }
        assert_eq!(sched.metrics.decode_steps, 0);
        assert_eq!(sched.metrics.prefill_batches, 0);
    }

    #[test]
    fn reload_adapters_swaps_generations_and_rejects_bad_sets() {
        use crate::model::Checkpoint;
        let (engine, adapters) = tiny();
        let mut sched = Scheduler::new(engine, adapters, SchedulerConfig::default()).unwrap();
        sched.submit("a", vec![1, 2, 3], 3, u32::MAX).unwrap();
        let before = sched.run_until_idle().unwrap();
        assert_eq!(before.len(), 1);

        // New generation: full-coverage adapters under new task names.
        let new_store = {
            let mut s = AdapterStore::new();
            s.insert("x", sched.engine().model().extract_adapter(true));
            s
        };
        assert_eq!(sched.reload_adapters(new_store).unwrap(), 1);
        assert!(sched.has_task("x"));
        assert!(!sched.has_task("a"), "old generation replaced");
        sched.submit("x", vec![1, 2], 2, u32::MAX).unwrap();
        assert_eq!(sched.run_until_idle().unwrap().len(), 1);

        // A partial adapter set is rejected even though the scheduler
        // itself is not in strict mode — and the live set keeps serving.
        let mut bad = AdapterStore::new();
        let mut partial = Checkpoint::new();
        let m = sched.engine().model().matrix("layers.0.attn.q").unwrap();
        partial.insert("layers.0.attn.q.s", m.scales.clone());
        bad.insert("broken", partial);
        let err = sched.reload_adapters(bad).unwrap_err().to_string();
        assert!(err.contains("strict adapter coverage"), "{err}");
        assert!(sched.has_task("x"), "failed reload must leave the live set");
        sched.submit("x", vec![3], 2, u32::MAX).unwrap();
        assert_eq!(sched.run_until_idle().unwrap().len(), 1);
    }

    #[test]
    fn unknown_task_is_an_error() {
        let (engine, adapters) = tiny();
        let mut sched = Scheduler::new(engine, adapters, SchedulerConfig::default()).unwrap();
        assert!(!sched.has_task("nope"));
        assert!(sched.has_task("a"));
        sched.submit("nope", vec![1], 3, u32::MAX).unwrap();
        assert!(sched.run_until_idle().is_err());
    }

    #[test]
    fn strict_coverage_rejects_partial_adapters_at_registration() {
        use crate::model::Checkpoint;
        // A partial adapter (one projection's scales only) registers
        // fine by default and serves with base fallback…
        let partial_store = |engine: &Engine| {
            let mut a = Checkpoint::new();
            let m = engine.model().matrix("layers.0.attn.q").unwrap();
            a.insert("layers.0.attn.q.s", m.scales.clone());
            let mut store = AdapterStore::new();
            store.insert("partial", a);
            store
        };
        let (engine, _) = tiny();
        let store = partial_store(&engine);
        let mut sched = Scheduler::new(engine, store, SchedulerConfig::default()).unwrap();
        sched.submit("partial", vec![1, 2, 3], 3, u32::MAX).unwrap();
        let r = sched.run_until_idle().unwrap();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].tokens.len(), 3);

        // …but strict-coverage mode rejects it at registration.
        let (engine, _) = tiny();
        let store = partial_store(&engine);
        let strict = SchedulerConfig { strict_coverage: true, ..SchedulerConfig::default() };
        let err = Scheduler::new(engine, store, strict);
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("strict adapter coverage"), "{msg}");
        assert!(msg.contains("partial"), "{msg}");

        // Full-coverage adapters pass strict mode (synth adapters carry
        // every s and z tensor), and an s-only full adapter also passes
        // (all-or-none zero coverage).
        let (engine, adapters) = tiny();
        let mut sched = Scheduler::new(engine, adapters, strict).unwrap();
        sched.submit("a", vec![4, 5], 2, u32::MAX).unwrap();
        assert_eq!(sched.run_until_idle().unwrap().len(), 1);
        let (engine, _) = tiny();
        let s_only = engine.model().extract_adapter(false);
        assert!(engine.adapter_coverage_gaps(&s_only).is_empty());
        // Mixed zero coverage is a gap even with all scales present.
        let mut mixed = engine.model().extract_adapter(false);
        let m = engine.model().matrix("layers.0.attn.q").unwrap();
        mixed.insert("layers.0.attn.q.z", m.zeros.clone());
        assert!(!engine.adapter_coverage_gaps(&mixed).is_empty());
    }
}
