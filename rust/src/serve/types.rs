//! Shared serving vocabulary — the request/response/metrics/adapter types
//! used by *both* serving paths: the host decode engine
//! ([`serve::engine`](crate::serve::engine) / [`serve::scheduler`](crate::serve::scheduler))
//! and, with `--features xla`, the artifact-driven `coordinator`.
//!
//! These types used to live inside the `coordinator` module and were
//! therefore gated behind the `xla` feature; the host engine and the
//! coordinator now share one vocabulary (the coordinator re-exports them),
//! so a request produced for one backend is valid for the other.

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

use crate::model::Checkpoint;
use crate::util::stats::{mean, percentile};

/// Named task adapters (the paper's s₀+Δs per task). An adapter is a
/// [`Checkpoint`] holding only the f32 scale (and optionally zero-point)
/// vectors of the quantized projections — kilobytes per task. The packed
/// integer codes are shared by every task and are never part of an
/// adapter: task switching is a scale swap, codes never move.
#[derive(Default)]
pub struct AdapterStore {
    adapters: HashMap<String, Checkpoint>,
}

impl AdapterStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, task: impl Into<String>, adapter: Checkpoint) {
        self.adapters.insert(task.into(), adapter);
    }

    pub fn get(&self, task: &str) -> Option<&Checkpoint> {
        self.adapters.get(task)
    }

    pub fn tasks(&self) -> Vec<&str> {
        let mut t: Vec<&str> = self.adapters.keys().map(|s| s.as_str()).collect();
        t.sort();
        t
    }

    /// Total bytes across all adapters (they are tiny — that's the point).
    pub fn total_bytes(&self) -> u64 {
        self.adapters
            .values()
            .map(|a| a.n_params() as u64 * 4)
            .sum()
    }

    /// Write every adapter as `<task>.adapter` into `dir`. Each file is
    /// a checksummed container written atomically
    /// ([`Checkpoint::save`] goes through `store::format::atomic_write`),
    /// so a crash mid-save never leaves a torn adapter under a real name.
    pub fn save_all(&self, dir: &Path) -> Result<()> {
        for (task, a) in &self.adapters {
            a.save(&dir.join(format!("{task}.adapter")))?;
        }
        Ok(())
    }

    /// Load every `*.adapter` in `dir`. Hidden files (dotfiles — editor
    /// swap, in-progress temp writes) and entries without the `.adapter`
    /// suffix are skipped silently; a file that *is* named like an
    /// adapter but fails to load (truncated, checksum mismatch, not a
    /// checkpoint) is skipped with a warning naming the offending path —
    /// one bad file never aborts the whole directory load.
    pub fn load_dir(dir: &Path) -> Result<AdapterStore> {
        let mut store = AdapterStore::new();
        for entry in std::fs::read_dir(dir)
            .map_err(|e| anyhow::anyhow!("reading adapter dir {}: {e}", dir.display()))?
        {
            let p = entry?.path();
            if !p.is_file() {
                continue;
            }
            let Some(name) = p.file_name().and_then(|s| s.to_str()) else {
                continue;
            };
            if name.starts_with('.') {
                continue;
            }
            let Some(task) = name.strip_suffix(".adapter") else {
                continue;
            };
            match Checkpoint::load(&p) {
                Ok(ck) => store.insert(task.to_string(), ck),
                Err(e) => crate::warn!(
                    "skipping adapter {}: {e:#} (task '{task}' will not be served)",
                    p.display()
                ),
            }
        }
        Ok(store)
    }
}

/// Coverage gaps of `adapter` against a set of quantized projection
/// prefixes — the ONE strict-coverage rule both serving paths enforce
/// at registration (host `serve::Scheduler`, xla `Coordinator`): every
/// prefix must receive a `.s` tensor, and `.z` tensors must cover
/// either every prefix or none (mixed zero coverage is as much a
/// layout drift as a missing scale). Returns the missing tensor names;
/// empty means full coverage.
pub fn adapter_coverage_gaps(prefixes: &[String], adapter: &Checkpoint) -> Vec<String> {
    let any_z = prefixes.iter().any(|p| adapter.get(&format!("{p}.z")).is_some());
    let mut gaps = Vec::new();
    for p in prefixes {
        if adapter.get(&format!("{p}.s")).is_none() {
            gaps.push(format!("{p}.s"));
        }
        if any_z && adapter.get(&format!("{p}.z")).is_none() {
            gaps.push(format!("{p}.z"));
        }
    }
    gaps
}

/// Strict-coverage registration check over a whole [`AdapterStore`]:
/// errors on the first task whose adapter leaves
/// [`adapter_coverage_gaps`] against `prefixes` — the shared gate both
/// the host `serve::Scheduler` and the xla `Coordinator` run when
/// [`BatcherConfig::strict_coverage`] is set.
pub fn validate_coverage(prefixes: &[String], adapters: &AdapterStore) -> Result<()> {
    for task in adapters.tasks() {
        let a = adapters.get(task).expect("task listed by the store");
        let gaps = adapter_coverage_gaps(prefixes, a);
        if !gaps.is_empty() {
            anyhow::bail!(
                "strict adapter coverage: task '{task}' leaves {} projection \
                 tensor(s) uncovered (first: {}) — re-export the adapter with \
                 full coverage or disable strict_coverage",
                gaps.len(),
                gaps[0]
            );
        }
    }
    Ok(())
}

/// One generation request: decode up to `max_new` tokens after `prompt`
/// with task `task`'s adapter, stopping early if `stop` is sampled (the
/// stop id itself never appears in the response tokens).
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub task: String,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub stop: u32,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub task: String,
    pub tokens: Vec<u32>,
    pub queue_s: f64,
    pub latency_s: f64,
}

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Max requests decoded together (host: engine batch; xla: ≤ the
    /// artifact's batch dim).
    pub max_batch: usize,
    /// Strict adapter-coverage mode: reject adapters that do not cover
    /// every packed projection at registration, instead of silently
    /// serving uncovered projections at base scales. Deployments that
    /// want coverage mismatches surfaced (a truncated adapter file, a
    /// layout drift between tuner and server) turn this on; the default
    /// keeps the partial-adapter behavior (uncovered projections revert
    /// to base — see `Engine::apply_adapter`).
    pub strict_coverage: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, strict_coverage: false }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub completed: usize,
    pub generated_tokens: usize,
    pub latencies_s: Vec<f64>,
    pub queue_s: Vec<f64>,
    /// Wall time of each real task switch (scale swap or full reload);
    /// same-task groups record nothing.
    pub swap_times_s: Vec<f64>,
    pub decode_steps: usize,
    /// Engine prefill passes (host path: one per cross-request admit
    /// batch — fewer than `completed` means prompts shared fused GEMMs).
    pub prefill_batches: usize,
    /// Prompt tokens consumed across all prefill passes.
    pub prefill_tokens: usize,
    pub wall_s: f64,
}

impl ServeMetrics {
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 { self.generated_tokens as f64 / self.wall_s } else { 0.0 }
    }

    pub fn p50_latency(&self) -> f64 {
        if self.latencies_s.is_empty() { 0.0 } else { percentile(&self.latencies_s, 50.0) }
    }

    pub fn p99_latency(&self) -> f64 {
        if self.latencies_s.is_empty() { 0.0 } else { percentile(&self.latencies_s, 99.0) }
    }

    pub fn mean_swap_s(&self) -> f64 {
        mean(&self.swap_times_s)
    }

    /// p99 task-switch wall time — the ROADMAP's switch-latency target.
    pub fn p99_swap_s(&self) -> f64 {
        if self.swap_times_s.is_empty() { 0.0 } else { percentile(&self.swap_times_s, 99.0) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn adapter_store_roundtrip() {
        let mut store = AdapterStore::new();
        let mut a = Checkpoint::new();
        a.insert("l.s", Tensor::full(&[4, 1], 0.5));
        store.insert("taskA", a);
        let mut b = Checkpoint::new();
        b.insert("l.s", Tensor::full(&[4, 1], 0.9));
        store.insert("taskB", b);
        assert_eq!(store.tasks(), vec!["taskA", "taskB"]);
        assert_eq!(store.total_bytes(), 2 * 4 * 4);

        let dir = std::env::temp_dir().join("peqa_test_adapters");
        std::fs::create_dir_all(&dir).unwrap();
        store.save_all(&dir).unwrap();
        let back = AdapterStore::load_dir(&dir).unwrap();
        assert_eq!(back.tasks(), vec!["taskA", "taskB"]);
        assert_eq!(back.get("taskB").unwrap().req("l.s").unwrap().data()[0], 0.9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_dir_skips_junk_and_bad_files_without_aborting() {
        let dir = std::env::temp_dir().join("peqa_test_adapters_junk");
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        let mut a = Checkpoint::new();
        a.insert("l.s", Tensor::full(&[4, 1], 0.5));
        a.save(&dir.join("good.adapter")).unwrap();
        // Junk that must be ignored: hidden files, wrong suffixes,
        // subdirectories, and a torn/garbage .adapter.
        std::fs::write(dir.join(".hidden.adapter"), b"editor swap").unwrap();
        std::fs::write(dir.join("notes.txt"), b"not an adapter").unwrap();
        std::fs::write(dir.join("torn.adapter"), b"PEQAS1\n\x01").unwrap();
        let store = AdapterStore::load_dir(&dir).unwrap();
        assert_eq!(store.tasks(), vec!["good"]);
        assert_eq!(store.get("good").unwrap().req("l.s").unwrap().data()[0], 0.5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_aggregation() {
        let mut m = ServeMetrics::default();
        m.generated_tokens = 100;
        m.wall_s = 2.0;
        m.latencies_s = vec![0.1, 0.2, 0.3, 0.4];
        m.swap_times_s = vec![0.001, 0.002, 0.003];
        assert_eq!(m.tokens_per_s(), 50.0);
        assert!((m.p50_latency() - 0.25).abs() < 1e-9);
        assert!(m.p99_latency() <= 0.4 && m.p99_latency() > 0.39);
        assert!((m.mean_swap_s() - 0.002).abs() < 1e-9);
        assert!(m.p99_swap_s() <= 0.003 && m.p99_swap_s() > 0.0029);
        // Empty metrics never divide by zero.
        let e = ServeMetrics::default();
        assert_eq!(e.tokens_per_s(), 0.0);
        assert_eq!(e.p50_latency(), 0.0);
        assert_eq!(e.p99_swap_s(), 0.0);
    }
}
