//! Shared serving vocabulary — the request/response/metrics/adapter types
//! used by *both* serving paths: the host decode engine
//! ([`serve::engine`](crate::serve::engine) / [`serve::scheduler`](crate::serve::scheduler))
//! and, with `--features xla`, the artifact-driven `coordinator`.
//!
//! These types used to live inside the `coordinator` module and were
//! therefore gated behind the `xla` feature; the host engine and the
//! coordinator now share one vocabulary (the coordinator re-exports them),
//! so a request produced for one backend is valid for the other.

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

use crate::model::Checkpoint;
use crate::util::stats::{mean, percentile};

/// Named task adapters (the paper's s₀+Δs per task). An adapter is a
/// [`Checkpoint`] holding only the f32 scale (and optionally zero-point)
/// vectors of the quantized projections — kilobytes per task. The packed
/// integer codes are shared by every task and are never part of an
/// adapter: task switching is a scale swap, codes never move.
///
/// Cloning copies the f32 scale/zero checkpoints only — kilobytes per
/// task — which is what lets every engine-pool worker own its own
/// store while the packed codes stay shared.
#[derive(Clone, Default)]
pub struct AdapterStore {
    adapters: HashMap<String, Checkpoint>,
}

impl AdapterStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, task: impl Into<String>, adapter: Checkpoint) {
        self.adapters.insert(task.into(), adapter);
    }

    pub fn get(&self, task: &str) -> Option<&Checkpoint> {
        self.adapters.get(task)
    }

    pub fn tasks(&self) -> Vec<&str> {
        let mut t: Vec<&str> = self.adapters.keys().map(|s| s.as_str()).collect();
        t.sort();
        t
    }

    /// Total bytes across all adapters (they are tiny — that's the point).
    pub fn total_bytes(&self) -> u64 {
        self.adapters
            .values()
            .map(|a| a.n_params() as u64 * 4)
            .sum()
    }

    /// Write every adapter as `<task>.adapter` into `dir`. Each file is
    /// a checksummed container written atomically
    /// ([`Checkpoint::save`] goes through `store::format::atomic_write`),
    /// so a crash mid-save never leaves a torn adapter under a real name.
    pub fn save_all(&self, dir: &Path) -> Result<()> {
        for (task, a) in &self.adapters {
            a.save(&dir.join(format!("{task}.adapter")))?;
        }
        Ok(())
    }

    /// Load every `*.adapter` in `dir`. Hidden files (dotfiles — editor
    /// swap, in-progress temp writes) and entries without the `.adapter`
    /// suffix are skipped silently; a file that *is* named like an
    /// adapter but fails to load (truncated, checksum mismatch, not a
    /// checkpoint) is skipped with a warning naming the offending path —
    /// one bad file never aborts the whole directory load.
    pub fn load_dir(dir: &Path) -> Result<AdapterStore> {
        let mut store = AdapterStore::new();
        for entry in std::fs::read_dir(dir)
            .map_err(|e| anyhow::anyhow!("reading adapter dir {}: {e}", dir.display()))?
        {
            let p = entry?.path();
            if !p.is_file() {
                continue;
            }
            let Some(name) = p.file_name().and_then(|s| s.to_str()) else {
                continue;
            };
            if name.starts_with('.') {
                continue;
            }
            let Some(task) = name.strip_suffix(".adapter") else {
                continue;
            };
            match Checkpoint::load(&p) {
                Ok(ck) => store.insert(task.to_string(), ck),
                Err(e) => crate::warn!(
                    "skipping adapter {}: {e:#} (task '{task}' will not be served)",
                    p.display()
                ),
            }
        }
        Ok(store)
    }
}

/// Coverage gaps of `adapter` against a set of quantized projection
/// prefixes — the ONE strict-coverage rule both serving paths enforce
/// at registration (host `serve::Scheduler`, xla `Coordinator`): every
/// prefix must receive a `.s` tensor, and `.z` tensors must cover
/// either every prefix or none (mixed zero coverage is as much a
/// layout drift as a missing scale). Returns the missing tensor names;
/// empty means full coverage.
pub fn adapter_coverage_gaps(prefixes: &[String], adapter: &Checkpoint) -> Vec<String> {
    let any_z = prefixes.iter().any(|p| adapter.get(&format!("{p}.z")).is_some());
    let mut gaps = Vec::new();
    for p in prefixes {
        if adapter.get(&format!("{p}.s")).is_none() {
            gaps.push(format!("{p}.s"));
        }
        if any_z && adapter.get(&format!("{p}.z")).is_none() {
            gaps.push(format!("{p}.z"));
        }
    }
    gaps
}

/// Strict-coverage registration check over a whole [`AdapterStore`]:
/// errors on the first task whose adapter leaves
/// [`adapter_coverage_gaps`] against `prefixes` — the shared gate both
/// the host `serve::Scheduler` and the xla `Coordinator` run when
/// [`BatcherConfig::strict_coverage`] is set.
pub fn validate_coverage(prefixes: &[String], adapters: &AdapterStore) -> Result<()> {
    for task in adapters.tasks() {
        // peqa-lint: allow(panic-free-paths) -- `task` is iterated from
        // this very store's tasks(); a miss is an AdapterStore bug, and
        // this gate runs at registration time, not per request.
        let a = adapters.get(task).expect("task listed by the store");
        let gaps = adapter_coverage_gaps(prefixes, a);
        if !gaps.is_empty() {
            anyhow::bail!(
                "strict adapter coverage: task '{task}' leaves {} projection \
                 tensor(s) uncovered (first: {}) — re-export the adapter with \
                 full coverage or disable strict_coverage",
                gaps.len(),
                gaps[0]
            );
        }
    }
    Ok(())
}

/// One generation request: decode up to `max_new` tokens after `prompt`
/// with task `task`'s adapter, stopping early if `stop` is sampled (the
/// stop id itself never appears in the response tokens).
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub task: String,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub stop: u32,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub task: String,
    pub tokens: Vec<u32>,
    pub queue_s: f64,
    pub latency_s: f64,
}

/// Typed serving failure — what admission control and the engine pool
/// hand back instead of an unbounded queue or a stringly error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Backpressure: the task's bounded ingress queue is full. The
    /// request was rejected at submit time — it never queued, nothing
    /// was decoded. Clients retry with backoff or route elsewhere.
    Overloaded { task: String, depth: usize, cap: usize },
    /// Deadline shedding: the request sat queued past its deadline and
    /// was dropped at dispatch instead of burning decode steps on an
    /// answer nobody is still waiting for.
    DeadlineExceeded { task: String, waited_ms: u64, deadline_ms: u64 },
    /// The prompt alone exceeds the per-sequence KV capacity (attention
    /// window): decoding would slide the window past the prompt's own
    /// tokens before the first generated one. Rejected at submit time —
    /// nothing was queued or decoded.
    PromptTooLong { len: usize, cap: usize },
    /// Paged-KV admission: the request needs more KV pages than the
    /// pool will *ever* have free (`--kv-pages` too small for this
    /// prompt+max_new at the configured page size). Transient pressure
    /// waits in the queue instead; this variant is only for requests
    /// that could never be staffed.
    KvExhausted { task: String, need: usize, total: usize },
    /// Everything else (unknown task, decode failure, shutdown),
    /// carried as text.
    Failed(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { task, depth, cap } => write!(
                f,
                "overloaded: task '{task}' ingress queue at {depth}/{cap} — retry with backoff"
            ),
            ServeError::DeadlineExceeded { task, waited_ms, deadline_ms } => write!(
                f,
                "deadline exceeded: task '{task}' request queued {waited_ms} ms \
                 (deadline {deadline_ms} ms) — shed at dispatch"
            ),
            ServeError::PromptTooLong { len, cap } => write!(
                f,
                "prompt too long: {len} tokens exceed the KV window capacity {cap} — \
                 raise --window or shorten the prompt"
            ),
            ServeError::KvExhausted { task, need, total } => write!(
                f,
                "kv exhausted: task '{task}' request needs {need} KV pages but the pool \
                 only has {total} — raise --kv-pages or lower max_new"
            ),
            ServeError::Failed(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One event on a streaming reply channel ([`super::pool::PoolHandle::submit_stream`] /
/// [`super::server::ServerHandle::submit_stream`]): zero or more
/// `Token`s as they are accepted by the decode loop, terminated by
/// exactly one `Done` (carrying the same response the non-streaming
/// path returns — its `tokens` are bitwise the concatenated `Token`
/// events) or one `Error`.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    Token(u32),
    Done(GenResponse),
    Error(ServeError),
}

/// Drain a streaming reply to completion: returns the streamed tokens
/// in arrival order plus the final response. Errors if the stream ends
/// without a `Done` (worker died) or delivers an `Error` event.
pub fn collect_stream(
    rx: &std::sync::mpsc::Receiver<StreamEvent>,
) -> Result<(Vec<u32>, GenResponse), ServeError> {
    let mut tokens = Vec::new();
    loop {
        match rx.recv() {
            Ok(StreamEvent::Token(t)) => tokens.push(t),
            Ok(StreamEvent::Done(resp)) => return Ok((tokens, resp)),
            Ok(StreamEvent::Error(e)) => return Err(e),
            Err(_) => return Err(ServeError::Failed("stream dropped before Done".into())),
        }
    }
}

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Max requests decoded together (host: engine batch; xla: ≤ the
    /// artifact's batch dim).
    pub max_batch: usize,
    /// Strict adapter-coverage mode: reject adapters that do not cover
    /// every packed projection at registration, instead of silently
    /// serving uncovered projections at base scales. Deployments that
    /// want coverage mismatches surfaced (a truncated adapter file, a
    /// layout drift between tuner and server) turn this on; the default
    /// keeps the partial-adapter behavior (uncovered projections revert
    /// to base — see `Engine::apply_adapter`).
    pub strict_coverage: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, strict_coverage: false }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub completed: usize,
    pub generated_tokens: usize,
    pub latencies_s: Vec<f64>,
    pub queue_s: Vec<f64>,
    /// Wall time of each real task switch (scale swap or full reload);
    /// same-task groups record nothing.
    pub swap_times_s: Vec<f64>,
    pub decode_steps: usize,
    /// Engine prefill passes (host path: one per cross-request admit
    /// batch — fewer than `completed` means prompts shared fused GEMMs).
    pub prefill_batches: usize,
    /// Prompt tokens consumed across all prefill passes.
    pub prefill_tokens: usize,
    pub wall_s: f64,
    /// Per-request time-to-first-token: submit → first accepted token
    /// (requests that finish with zero tokens record nothing).
    pub ttft_s: Vec<f64>,
    /// Gaps between consecutive accepted tokens of one request (the
    /// streaming cadence a client observes after the first token).
    pub inter_token_s: Vec<f64>,
    /// High-water mark of queued (not-yet-admitted) requests.
    pub queue_depth_max: usize,
    /// Requests rejected by admission control (bounded-queue overflow)
    /// or dropped by deadline shedding — typed errors, never silent.
    pub shed_count: usize,
    /// Dispatcher batches kept on a worker's current task by affinity
    /// while an older request of another task was waiting — each one is
    /// a scale swap the affinity policy avoided.
    pub swaps_avoided: usize,
    /// Paged KV: high-water mark of pages in use at once (0 on the ring
    /// backend). The memory claim of the paged design: N same-prefix
    /// clients peak near 1× the prefix's pages, not N×.
    pub kv_pages_peak: usize,
    /// Paged KV: prompt-prefix pages attached via copy-on-write sharing
    /// instead of being prefilled again (each is a page of prefill work
    /// and a page of memory saved).
    pub kv_pages_shared: usize,
    /// Requests rejected at submit because they could never fit the
    /// page pool ([`ServeError::KvExhausted`]).
    pub kv_exhausted_count: usize,
}

impl ServeMetrics {
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 { self.generated_tokens as f64 / self.wall_s } else { 0.0 }
    }

    pub fn p50_latency(&self) -> f64 {
        if self.latencies_s.is_empty() { 0.0 } else { percentile(&self.latencies_s, 50.0) }
    }

    pub fn p99_latency(&self) -> f64 {
        if self.latencies_s.is_empty() { 0.0 } else { percentile(&self.latencies_s, 99.0) }
    }

    pub fn mean_swap_s(&self) -> f64 {
        mean(&self.swap_times_s)
    }

    /// p99 task-switch wall time — the ROADMAP's switch-latency target.
    pub fn p99_swap_s(&self) -> f64 {
        if self.swap_times_s.is_empty() { 0.0 } else { percentile(&self.swap_times_s, 99.0) }
    }

    pub fn p50_ttft_s(&self) -> f64 {
        if self.ttft_s.is_empty() { 0.0 } else { percentile(&self.ttft_s, 50.0) }
    }

    pub fn p99_ttft_s(&self) -> f64 {
        if self.ttft_s.is_empty() { 0.0 } else { percentile(&self.ttft_s, 99.0) }
    }

    /// p99 inter-token gap — the streaming SLO metric (flat under load
    /// is the pool's whole point).
    pub fn p99_inter_token_s(&self) -> f64 {
        if self.inter_token_s.is_empty() { 0.0 } else { percentile(&self.inter_token_s, 99.0) }
    }

    /// Fold another metrics block into this one (the engine pool merges
    /// per-worker scheduler metrics plus the dispatcher's admission
    /// counters into one client-visible snapshot). Counters add,
    /// latency samples concatenate, high-water marks take the max;
    /// `wall_s` takes the max too — workers run concurrently, so
    /// summing their walls would overstate elapsed time.
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.completed += other.completed;
        self.generated_tokens += other.generated_tokens;
        self.latencies_s.extend_from_slice(&other.latencies_s);
        self.queue_s.extend_from_slice(&other.queue_s);
        self.swap_times_s.extend_from_slice(&other.swap_times_s);
        self.decode_steps += other.decode_steps;
        self.prefill_batches += other.prefill_batches;
        self.prefill_tokens += other.prefill_tokens;
        self.wall_s = self.wall_s.max(other.wall_s);
        self.ttft_s.extend_from_slice(&other.ttft_s);
        self.inter_token_s.extend_from_slice(&other.inter_token_s);
        self.queue_depth_max = self.queue_depth_max.max(other.queue_depth_max);
        self.shed_count += other.shed_count;
        self.swaps_avoided += other.swaps_avoided;
        // Per-worker page pools are disjoint, so the fleet-wide peak is
        // conservatively the max of the worker peaks (each worker's pages
        // never alias another's); shared/exhausted are plain counters.
        self.kv_pages_peak = self.kv_pages_peak.max(other.kv_pages_peak);
        self.kv_pages_shared += other.kv_pages_shared;
        self.kv_exhausted_count += other.kv_exhausted_count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn adapter_store_roundtrip() {
        let mut store = AdapterStore::new();
        let mut a = Checkpoint::new();
        a.insert("l.s", Tensor::full(&[4, 1], 0.5));
        store.insert("taskA", a);
        let mut b = Checkpoint::new();
        b.insert("l.s", Tensor::full(&[4, 1], 0.9));
        store.insert("taskB", b);
        assert_eq!(store.tasks(), vec!["taskA", "taskB"]);
        assert_eq!(store.total_bytes(), 2 * 4 * 4);

        let dir = std::env::temp_dir().join("peqa_test_adapters");
        std::fs::create_dir_all(&dir).unwrap();
        store.save_all(&dir).unwrap();
        let back = AdapterStore::load_dir(&dir).unwrap();
        assert_eq!(back.tasks(), vec!["taskA", "taskB"]);
        assert_eq!(back.get("taskB").unwrap().req("l.s").unwrap().data()[0], 0.9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_dir_skips_junk_and_bad_files_without_aborting() {
        let dir = std::env::temp_dir().join("peqa_test_adapters_junk");
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        let mut a = Checkpoint::new();
        a.insert("l.s", Tensor::full(&[4, 1], 0.5));
        a.save(&dir.join("good.adapter")).unwrap();
        // Junk that must be ignored: hidden files, wrong suffixes,
        // subdirectories, and a torn/garbage .adapter.
        std::fs::write(dir.join(".hidden.adapter"), b"editor swap").unwrap();
        std::fs::write(dir.join("notes.txt"), b"not an adapter").unwrap();
        std::fs::write(dir.join("torn.adapter"), b"PEQAS1\n\x01").unwrap();
        let store = AdapterStore::load_dir(&dir).unwrap();
        assert_eq!(store.tasks(), vec!["good"]);
        assert_eq!(store.get("good").unwrap().req("l.s").unwrap().data()[0], 0.5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_aggregation() {
        let mut m = ServeMetrics::default();
        m.generated_tokens = 100;
        m.wall_s = 2.0;
        m.latencies_s = vec![0.1, 0.2, 0.3, 0.4];
        m.swap_times_s = vec![0.001, 0.002, 0.003];
        assert_eq!(m.tokens_per_s(), 50.0);
        assert!((m.p50_latency() - 0.25).abs() < 1e-9);
        assert!(m.p99_latency() <= 0.4 && m.p99_latency() > 0.39);
        assert!((m.mean_swap_s() - 0.002).abs() < 1e-9);
        assert!(m.p99_swap_s() <= 0.003 && m.p99_swap_s() > 0.0029);
        // Empty metrics never divide by zero.
        let e = ServeMetrics::default();
        assert_eq!(e.tokens_per_s(), 0.0);
        assert_eq!(e.p50_latency(), 0.0);
        assert_eq!(e.p99_swap_s(), 0.0);
        assert_eq!(e.p50_ttft_s(), 0.0);
        assert_eq!(e.p99_inter_token_s(), 0.0);
    }

    #[test]
    fn metrics_merge_adds_counters_and_maxes_watermarks() {
        let mut a = ServeMetrics::default();
        a.completed = 3;
        a.generated_tokens = 30;
        a.wall_s = 2.0;
        a.ttft_s = vec![0.01, 0.02];
        a.inter_token_s = vec![0.001];
        a.queue_depth_max = 4;
        a.shed_count = 1;
        a.swaps_avoided = 2;
        a.kv_pages_peak = 12;
        a.kv_pages_shared = 5;
        let mut b = ServeMetrics::default();
        b.completed = 2;
        b.generated_tokens = 20;
        b.wall_s = 3.0;
        b.ttft_s = vec![0.03];
        b.queue_depth_max = 7;
        b.swaps_avoided = 1;
        b.kv_pages_peak = 9;
        b.kv_pages_shared = 2;
        b.kv_exhausted_count = 1;
        a.merge(&b);
        assert_eq!(a.completed, 5);
        assert_eq!(a.generated_tokens, 50);
        assert_eq!(a.wall_s, 3.0, "concurrent workers: wall is a max, not a sum");
        assert_eq!(a.ttft_s.len(), 3);
        assert_eq!(a.inter_token_s.len(), 1);
        assert_eq!(a.queue_depth_max, 7);
        assert_eq!(a.shed_count, 1);
        assert_eq!(a.swaps_avoided, 3);
        assert_eq!(a.kv_pages_peak, 12, "disjoint pools: peak is a max");
        assert_eq!(a.kv_pages_shared, 7);
        assert_eq!(a.kv_exhausted_count, 1);
    }

    #[test]
    fn serve_error_display_and_stream_collect() {
        let e = ServeError::Overloaded { task: "a".into(), depth: 8, cap: 8 };
        assert!(e.to_string().contains("overloaded"));
        assert!(e.to_string().contains("8/8"));
        let d = ServeError::DeadlineExceeded { task: "a".into(), waited_ms: 50, deadline_ms: 10 };
        assert!(d.to_string().contains("deadline"));
        let p = ServeError::PromptTooLong { len: 300, cap: 256 };
        assert!(p.to_string().contains("300"));
        assert!(p.to_string().contains("256"));
        let k = ServeError::KvExhausted { task: "a".into(), need: 9, total: 4 };
        assert!(k.to_string().contains("9"));
        assert!(k.to_string().contains("--kv-pages"));

        let (tx, rx) = std::sync::mpsc::sync_channel(8);
        let resp = GenResponse {
            id: 1,
            task: "a".into(),
            tokens: vec![5, 6],
            queue_s: 0.0,
            latency_s: 0.0,
        };
        tx.send(StreamEvent::Token(5)).unwrap();
        tx.send(StreamEvent::Token(6)).unwrap();
        tx.send(StreamEvent::Done(resp)).unwrap();
        let (tokens, done) = collect_stream(&rx).unwrap();
        assert_eq!(tokens, vec![5, 6]);
        assert_eq!(done.tokens, tokens);

        // A dropped sender before Done is a typed failure, not a hang.
        let (tx2, rx2) = std::sync::mpsc::sync_channel::<StreamEvent>(1);
        drop(tx2);
        assert!(collect_stream(&rx2).is_err());
    }
}
