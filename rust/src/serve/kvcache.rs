//! Per-sequence K/V ring buffers for incremental autoregressive decode.
//!
//! One [`KvCache`] belongs to one sequence. Storage is preallocated up
//! front as two `(n_layers, capacity, d_model)` f32 slabs and never
//! reallocated during decode — appending position `t` writes slot
//! `t % capacity`, so a sequence longer than `capacity` degrades to
//! sliding-window attention over the most recent `capacity` tokens
//! (keys are stored already rotated at their *absolute* RoPE position,
//! which keeps relative offsets correct across the wrap).
//!
//! The write/advance split exists because the engine processes all of a
//! token's layers before the token counts as appended: during a forward
//! step the engine calls [`KvCache::write`] once per layer at the same
//! absolute position, then [`KvCache::advance`] once the token (or
//! prefill block) is fully processed.
//!
//! [`KvSeq`] is the engine-facing sum of the two KV backends: this ring
//! (the bitwise oracle, and the default when `--kv-pages` is 0) and the
//! paged table of [`super::kvpage`] (fixed-size pages, copy-on-write
//! prefix sharing). Both store identical rows at identical ring slots,
//! so the engine's decode is bitwise the same through either.

use super::kvpage::PagedKvCache;

/// Preallocated per-sequence K/V ring buffer (see module docs).
#[derive(Clone, Debug)]
pub struct KvCache {
    n_layers: usize,
    d: usize,
    capacity: usize,
    /// Absolute sequence length appended so far (monotonic; slots ring).
    pos: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    /// `d` is the per-position row width (n_heads · head_dim).
    pub fn new(n_layers: usize, d: usize, capacity: usize) -> KvCache {
        // peqa-lint: allow(panic-free-paths) -- construction-time guard:
        // the geometry comes from a validated ModelGeom, so a zero here
        // is a programmer error, caught before any request is admitted.
        assert!(n_layers > 0 && d > 0 && capacity > 0, "degenerate kv cache");
        KvCache {
            n_layers,
            d,
            capacity,
            pos: 0,
            k: vec![0.0; n_layers * capacity * d],
            v: vec![0.0; n_layers * capacity * d],
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Absolute sequence length appended so far (RoPE position of the
    /// *next* token).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Number of positions currently resident (≤ capacity).
    pub fn len(&self) -> usize {
        self.pos.min(self.capacity)
    }

    pub fn is_empty(&self) -> bool {
        self.pos == 0
    }

    /// How many positions are attendable when the query sits at absolute
    /// position `abs` (inclusive of `abs` itself).
    pub fn window_len(&self, abs: usize) -> usize {
        (abs + 1).min(self.capacity)
    }

    #[inline]
    fn offset(&self, layer: usize, abs: usize) -> usize {
        debug_assert!(layer < self.n_layers);
        (layer * self.capacity + abs % self.capacity) * self.d
    }

    /// Store the K/V rows of absolute position `abs` for `layer`
    /// (overwrites position `abs − capacity` once the ring wraps).
    pub fn write(&mut self, layer: usize, abs: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        let o = self.offset(layer, abs);
        self.k[o..o + self.d].copy_from_slice(k);
        self.v[o..o + self.d].copy_from_slice(v);
    }

    pub fn k_row(&self, layer: usize, abs: usize) -> &[f32] {
        let o = self.offset(layer, abs);
        &self.k[o..o + self.d]
    }

    pub fn v_row(&self, layer: usize, abs: usize) -> &[f32] {
        let o = self.offset(layer, abs);
        &self.v[o..o + self.d]
    }

    /// The attention window of a query at absolute position `abs` as at
    /// most two contiguous `(k, v)` row slabs in position order: the ring
    /// wraps at most once, so the window `[abs+1−window_len(abs), abs]`
    /// occupies one slab up to the end of the ring plus (possibly empty)
    /// one from its start. Row `j` of the concatenated slabs is position
    /// `abs + 1 − window_len(abs) + j`. This is what lets the engine's
    /// head-blocked attention stream K/V with contiguous reads instead of
    /// a per-position `k_row` offset computation.
    pub fn window_slabs(&self, layer: usize, abs: usize) -> [(&[f32], &[f32]); 2] {
        let n = self.window_len(abs);
        let start = abs + 1 - n;
        let s0 = start % self.capacity;
        let first = n.min(self.capacity - s0);
        let base = layer * self.capacity * self.d;
        let a = base + s0 * self.d;
        let rest = n - first;
        [
            (&self.k[a..a + first * self.d], &self.v[a..a + first * self.d]),
            (&self.k[base..base + rest * self.d], &self.v[base..base + rest * self.d]),
        ]
    }

    /// Mark `n` more positions as fully appended (all layers written).
    pub fn advance(&mut self, n: usize) {
        self.pos += n;
    }

    /// Forget the sequence but keep the allocation (slot reuse between
    /// requests in the scheduler).
    pub fn reset(&mut self) {
        self.pos = 0;
    }

    /// Preallocated bytes across K and V and all layers.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }
}

/// One decoding sequence's KV state, over either backend (module docs).
/// The engine writes and advances through this enum; the attention
/// kernel dispatches on it to stream the window as contiguous segments
/// (two slabs for the ring, a page walk for the paged table).
#[derive(Debug)]
pub enum KvSeq {
    Ring(KvCache),
    Paged(PagedKvCache),
}

impl KvSeq {
    pub fn capacity(&self) -> usize {
        match self {
            KvSeq::Ring(c) => c.capacity(),
            KvSeq::Paged(c) => c.capacity(),
        }
    }

    /// Absolute sequence length appended so far (RoPE position of the
    /// *next* token).
    pub fn pos(&self) -> usize {
        match self {
            KvSeq::Ring(c) => c.pos(),
            KvSeq::Paged(c) => c.pos(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            KvSeq::Ring(c) => c.len(),
            KvSeq::Paged(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.pos() == 0
    }

    /// How many positions are attendable when the query sits at absolute
    /// position `abs` (inclusive of `abs` itself).
    pub fn window_len(&self, abs: usize) -> usize {
        match self {
            KvSeq::Ring(c) => c.window_len(abs),
            KvSeq::Paged(c) => c.window_len(abs),
        }
    }

    /// Store the K/V rows of absolute position `abs` for `layer`. Paged
    /// sequences must have been [`PagedKvCache::prepare`]d for these
    /// positions by the scheduler first.
    pub fn write(&mut self, layer: usize, abs: usize, k: &[f32], v: &[f32]) {
        match self {
            KvSeq::Ring(c) => c.write(layer, abs, k, v),
            KvSeq::Paged(c) => c.write(layer, abs, k, v),
        }
    }

    /// Mark `n` more positions as fully appended (all layers written).
    pub fn advance(&mut self, n: usize) {
        match self {
            KvSeq::Ring(c) => c.advance(n),
            KvSeq::Paged(c) => c.advance(n),
        }
    }

    /// KV storage bytes reachable from this sequence.
    pub fn bytes(&self) -> usize {
        match self {
            KvSeq::Ring(c) => c.bytes(),
            KvSeq::Paged(c) => c.bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(tag: f32, d: usize) -> Vec<f32> {
        (0..d).map(|j| tag + j as f32).collect()
    }

    #[test]
    fn write_read_roundtrip_across_layers() {
        let d = 4;
        let mut c = KvCache::new(2, d, 8);
        assert!(c.is_empty());
        for t in 0..3usize {
            for layer in 0..2 {
                let tag = (10 * layer + t) as f32;
                c.write(layer, t, &row(tag, d), &row(tag + 0.5, d));
            }
            c.advance(1);
        }
        assert_eq!(c.pos(), 3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.k_row(1, 2), row(12.0, d).as_slice());
        assert_eq!(c.v_row(0, 1), row(1.5, d).as_slice());
        assert_eq!(c.bytes(), 2 * 2 * 8 * d * 4);
    }

    #[test]
    fn ring_wraps_and_window_shrinks_to_capacity() {
        let d = 2;
        let cap = 4;
        let mut c = KvCache::new(1, d, cap);
        for t in 0..6usize {
            c.write(0, t, &row(t as f32, d), &row(t as f32, d));
            c.advance(1);
        }
        assert_eq!(c.pos(), 6);
        assert_eq!(c.len(), cap);
        // Window at abs=5 covers abs 2..=5; abs 4 reuses slot of abs 0.
        assert_eq!(c.window_len(5), cap);
        assert_eq!(c.window_len(1), 2);
        for t in 2..6usize {
            assert_eq!(c.k_row(0, t), row(t as f32, d).as_slice(), "abs={t}");
        }
        // Slot aliasing: abs 4 and abs 0 share slot 0, latest write wins.
        assert_eq!(c.k_row(0, 4), c.k_row(0, 0));
    }

    #[test]
    fn window_slabs_cover_the_window_in_position_order() {
        let d = 2;
        let cap = 4;
        let mut c = KvCache::new(2, d, cap);
        for t in 0..7usize {
            for layer in 0..2 {
                let tag = (100 * layer + t) as f32;
                c.write(layer, t, &row(tag, d), &row(tag + 0.5, d));
            }
            c.advance(1);
        }
        for layer in 0..2 {
            for abs in [0usize, 2, 3, 5, 6] {
                let n = c.window_len(abs);
                let start = abs + 1 - n;
                let [(k1, v1), (k2, v2)] = c.window_slabs(layer, abs);
                assert_eq!(k1.len() + k2.len(), n * d, "abs={abs}");
                assert_eq!(v1.len() + v2.len(), n * d);
                let rows: Vec<&[f32]> =
                    k1.chunks_exact(d).chain(k2.chunks_exact(d)).collect();
                let vrows: Vec<&[f32]> =
                    v1.chunks_exact(d).chain(v2.chunks_exact(d)).collect();
                for (j, (kr, vr)) in rows.iter().zip(&vrows).enumerate() {
                    assert_eq!(*kr, c.k_row(layer, start + j), "layer={layer} abs={abs} j={j}");
                    assert_eq!(*vr, c.v_row(layer, start + j));
                }
            }
        }
    }

    #[test]
    fn reset_keeps_allocation() {
        let mut c = KvCache::new(1, 2, 4);
        c.write(0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        c.advance(1);
        let bytes = c.bytes();
        c.reset();
        assert!(c.is_empty());
        assert_eq!(c.bytes(), bytes);
        assert_eq!(c.capacity(), 4);
    }
}
