//! Host autoregressive decode engine over the fused packed kernel layer.
//!
//! The paper's deployment claim is that a PEQA model *serves* in its
//! quantized form: sub-4-bit integer codes stay bit-packed in memory,
//! every block projection runs through the fused quantized GEMM
//! (`quant::kernels::PackedMatrix::matmul_t` and its serving entry point
//! `matmul_t_rows_scratch`), and a task is nothing but a set of f32
//! scale/zero vectors. This module is that claim executed on a plain
//! host, no `xla` feature required.
//!
//! All block math — RMSNorm, rotary, the head-blocked causal attention
//! kernel, SwiGLU, the packed-projection call — lives in the shared
//! transformer compute core [`crate::model::blocks`]; this module is
//! the *serving driver* over it (KV caches, batching, scale swaps,
//! sampling). The host training backend (`train::host`) drives the very
//! same functions with a tape, so train-forward vs engine-prefill
//! parity is **bitwise** (tests/train_host.rs).
//!
//! * [`Engine`] — llama-family transformer forward from a
//!   [`PackedModel`]: embedding gather, RMSNorm, rotary positions,
//!   causal attention over per-sequence [`KvCache`]s, SwiGLU MLP,
//!   fp LM head. One multi-sequence core drives all three entry points:
//!   [`Engine::prefill`] consumes a block of prompt tokens of one
//!   sequence, [`Engine::prefill_batch`] prefills *several* queued
//!   prompts through the same fused GEMM calls (cross-request prefill
//!   batching), and [`Engine::decode_batch`] advances several sequences
//!   one token each. Per-sequence math is independent of batch
//!   composition and of the worker-thread count, so greedy decode is
//!   **bit-identical** across batch sizes, across prefill groupings,
//!   and across `PEQA_THREADS` settings.
//! * **Scratch arena** — every activation slab of the forward pass
//!   (normed rows, q/k/v, attention scores/context, gate/up/act/down,
//!   the kernel's yᵀ transpose buffer) lives in a per-engine [`Scratch`]
//!   that is grown once and reused across decode steps and prefill
//!   chunks; the steady-state loop performs no per-call allocation
//!   besides the returned logits.
//! * **Head-blocked attention** — instead of a scalar head-by-head loop
//!   that re-walks the KV window once per head, the kernel streams the
//!   window's contiguous K/V slabs ([`KvCache::window_slabs`]) once and
//!   scores/accumulates *all heads* per cached row with 4-way blocked
//!   dot products.
//! * [`Engine::apply_adapter`] — PEQA task switching: replaces the f32
//!   scale/zero tensors of adapter-covered projections and restores the
//!   construction-time base scales/zeros on every projection the
//!   adapter does *not* cover, so a swap never leaves the previous
//!   task's residue behind. The packed code buffers are never touched,
//!   cloned, or re-packed.
//! * [`Sampling`] / [`sample`] — greedy argmax and seeded top-k (total
//!   order even with NaN logits: NaN sorts last, never panics).
//! * [`reference_forward`] / [`reference_forward_windowed`] — the parity
//!   baselines: full (or sliding-window) causal attention over *dense
//!   dequantized* weights via the seed's `matmul_naive`. The engine must
//!   agree with them to ≤ 1e-4 (tests/serve_host.rs).
//!
//! Model geometry comes from [`ModelGeom`]: either a typed artifact
//! meta.json ([`ModelGeom::from_artifact`]) or inferred from the packed
//! tensors themselves ([`ModelGeom::infer`]; only `n_heads` cannot be
//! recovered from shapes).

use anyhow::{anyhow, bail, Result};

use super::kvcache::{KvCache, KvSeq};
use crate::model::blocks::{
    self, attend_seq_chunk, ensure, proj_into, rms_norm_rows, rms_norm_rows_into, rope_freqs,
    silu, AttnScratch, LayerNames, ProjScratch,
};
use crate::model::{Checkpoint, PackedModel};
use crate::runtime::ArtifactMeta;
use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Static transformer geometry of a served model (llama family:
/// RMSNorm + rotary + SwiGLU — the architecture the paper quantizes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelGeom {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl ModelGeom {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    fn validated(self) -> Result<ModelGeom> {
        if self.vocab == 0 || self.d_model == 0 || self.n_layers == 0 || self.d_ff == 0 {
            bail!("degenerate model geometry {self:?}");
        }
        if self.n_heads == 0 || self.d_model % self.n_heads != 0 {
            bail!("n_heads {} must divide d_model {}", self.n_heads, self.d_model);
        }
        if self.head_dim() % 2 != 0 {
            bail!("rotary positions need an even head_dim, got {}", self.head_dim());
        }
        Ok(self)
    }

    /// Geometry from a typed artifact meta.json (the canonical source —
    /// python/compile is the single source of truth for model shape).
    pub fn from_artifact(meta: &ArtifactMeta) -> Result<ModelGeom> {
        let m = meta
            .model
            .as_ref()
            .ok_or_else(|| anyhow!("artifact '{}' carries no model geometry", meta.name))?;
        if m.family != "llama" {
            bail!(
                "host engine serves the llama family (RMSNorm/rope/SwiGLU); \
                 artifact '{}' is '{}'",
                meta.name,
                m.family
            );
        }
        ModelGeom {
            vocab: m.vocab,
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            d_ff: m.d_ff,
        }
        .validated()
    }

    /// Infer geometry from a packed model's tensors. `n_heads` cannot be
    /// recovered from shapes and must be supplied by the caller.
    pub fn infer(model: &PackedModel, n_heads: usize) -> Result<ModelGeom> {
        let embed = model
            .fp_tensor("embed")
            .ok_or_else(|| anyhow!("packed model has no 'embed' tensor"))?;
        let (vocab, d_model) = embed.dims2()?;
        let mut n_layers = 0usize;
        for name in model.tensor_names() {
            if let Some(rest) = name.strip_prefix("layers.") {
                if let Some(i) = rest.split('.').next().and_then(|s| s.parse::<usize>().ok()) {
                    n_layers = n_layers.max(i + 1);
                }
            }
        }
        if n_layers == 0 {
            bail!("packed model has no 'layers.*' tensors — nothing to serve");
        }
        let d_ff = if let Some(m) = model.matrix("layers.0.mlp.gate") {
            m.rows
        } else if let Some(t) = model.fp_tensor("layers.0.mlp.gate.w") {
            t.dims2()?.0
        } else {
            bail!(
                "packed model has no 'layers.0.mlp.gate' projection \
                 (host engine serves the llama family)"
            );
        };
        ModelGeom { vocab, d_model, n_layers, n_heads, d_ff }.validated()
    }
}

/// Token selection policy for the decode loop.
#[derive(Clone, Copy, Debug)]
pub enum Sampling {
    /// Deterministic argmax (first index wins ties) — the mode the
    /// bit-identical batch/thread invariance guarantees apply to.
    Greedy,
    /// Sample from the `k` highest logits at `temperature`, drawn from a
    /// seeded [`Pcg32`] stream (deterministic given the stream order).
    TopK { k: usize, temperature: f32 },
}

/// Select the next token from one logits row.
pub fn sample(logits: &[f32], sampling: Sampling, rng: &mut Pcg32) -> u32 {
    match sampling {
        Sampling::Greedy => argmax(logits),
        Sampling::TopK { k, temperature } => {
            let k = k.max(1).min(logits.len());
            // Descending by logit with NaN sorting LAST and ties broken
            // by index. `partial_cmp(..).unwrap_or(Equal)` is NOT a total
            // order once NaN and non-NaN mix (NaN == everything breaks
            // transitivity) and can make `select_nth_unstable_by` /
            // `sort_by` panic; keying on (is_nan, total_cmp desc, index)
            // is total, so a NaN-poisoned logits row degrades to
            // "ignore the NaNs" instead of aborting the server.
            let cmp = |a: &usize, b: &usize| {
                let (fa, fb) = (logits[*a], logits[*b]);
                match (fa.is_nan(), fb.is_nan()) {
                    (true, true) => a.cmp(b),
                    (true, false) => std::cmp::Ordering::Greater,
                    (false, true) => std::cmp::Ordering::Less,
                    (false, false) => fb.total_cmp(&fa).then(a.cmp(b)),
                }
            };
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, cmp);
                idx.truncate(k);
            }
            idx.sort_by(cmp);
            let top = logits[idx[0]];
            if top.is_nan() {
                // Every candidate is NaN — nothing to weight; pick the
                // lowest index deterministically.
                return idx[0] as u32;
            }
            let t = temperature.max(1e-6);
            let ws: Vec<f32> = idx
                .iter()
                .map(|&i| {
                    let v = logits[i];
                    if v.is_nan() { 0.0 } else { ((v - top) / t).exp() }
                })
                .collect();
            let total: f32 = ws.iter().sum();
            if !(total > 0.0) || !total.is_finite() {
                return idx[0] as u32;
            }
            let mut r = rng.f32() * total;
            // Fallback for fp rounding (r can stay > 0 after the last
            // positive weight): the last positively-weighted index, never
            // a zero-weight NaN candidate at the tail.
            let mut last_pos = 0usize;
            for (j, &w) in ws.iter().enumerate() {
                if w > 0.0 {
                    last_pos = j;
                }
                r -= w;
                if r <= 0.0 {
                    return idx[j] as u32;
                }
            }
            idx[last_pos] as u32
        }
    }
}

/// First-index argmax (NaN-safe: comparisons against NaN keep the best).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for i in 1..logits.len() {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    best as u32
}

/// Host decode engine over a [`PackedModel`] (see module docs).
pub struct Engine {
    model: PackedModel,
    geom: ModelGeom,
    threads: usize,
    /// Rotary frequency table, head_dim/2 entries.
    freqs: Vec<f32>,
    /// "lm_head" or "embed" (tied head).
    head_name: &'static str,
    /// Per-layer tensor names resolved once at construction, so the
    /// per-token decode loop does no string formatting.
    layer_names: Vec<LayerNames>,
    /// Construction-time (scales, zeros) snapshot per packed projection,
    /// restored on every [`Engine::apply_adapter`] for projections the
    /// incoming adapter does not cover — a partial adapter must never
    /// leave the previous task's scales behind.
    base_sz: Vec<(String, Tensor, Tensor)>,
    /// Prefixes whose scales / zeros currently hold *adapter* values
    /// (everything else is at base). Lets a swap restore only what the
    /// previous adapter actually touched, keeping partial-adapter swap
    /// cost O(changed tensors) instead of O(all scales).
    swapped_s: std::collections::HashSet<String>,
    swapped_z: std::collections::HashSet<String>,
    /// Reused activation slabs (see module docs) — the reason the decode
    /// entry points take `&mut self`.
    scratch: Scratch,
}

/// Per-engine activation arena: grown to the high-water mark once, then
/// reused across decode steps and prefill chunks. Buffers hold stale
/// data between calls; every consumer writes its full `[..len]` range
/// before reading, which keeps results bitwise independent of history.
#[derive(Default)]
struct Scratch {
    /// Residual-stream rows, `(rows, d_model)`.
    x: Vec<f32>,
    /// Pre-norm rows shared by the attention and MLP halves.
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention context rows.
    ctx: Vec<f32>,
    o: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
    down: Vec<f32>,
    /// Per-worker attention scratch: the attention pass shards batch
    /// rows (sequences) over `std::thread::scope` workers, and each
    /// worker owns one of these (grown to the worker count once).
    attn: Vec<AttnScratch>,
    /// Last-position rows gathered for the LM head, `(n_seqs, d_model)`.
    last: Vec<f32>,
    /// Per-sequence token counts of the current call — the ragged span
    /// shape handed to the shared projection call
    /// ([`blocks::proj_into`]).
    spans: Vec<usize>,
    /// Shared kernel scratch (the fused GEMM's yᵀ buffer), owned here so
    /// the steady-state decode loop does no per-call kernel allocation.
    proj: ProjScratch,
}

impl Engine {
    /// Validate that `model` carries a complete llama-family layout for
    /// `geom` and wrap it for serving. `threads` pins the fused-kernel
    /// worker count (results are bit-identical for any value).
    pub fn from_packed(model: PackedModel, geom: ModelGeom, threads: usize) -> Result<Engine> {
        let geom = geom.validated()?;
        let d = geom.d_model;
        let embed = model
            .fp_tensor("embed")
            .ok_or_else(|| anyhow!("packed model missing fp tensor 'embed'"))?;
        if embed.shape() != [geom.vocab, d].as_slice() {
            bail!("'embed' is {:?}, geometry wants [{}, {d}]", embed.shape(), geom.vocab);
        }
        let head_name = if let Some(h) = model.fp_tensor("lm_head") {
            if h.shape() != [geom.vocab, d].as_slice() {
                bail!("'lm_head' is {:?}, geometry wants [{}, {d}]", h.shape(), geom.vocab);
            }
            "lm_head"
        } else {
            "embed" // tied head
        };
        let fg = model
            .fp_tensor("final_norm.g")
            .ok_or_else(|| anyhow!("packed model missing 'final_norm.g'"))?;
        if fg.shape() != [d].as_slice() {
            bail!("'final_norm.g' is {:?}, expected [{d}]", fg.shape());
        }
        let mut layer_names = Vec::with_capacity(geom.n_layers);
        for i in 0..geom.n_layers {
            let lp = format!("layers.{i}");
            for ln in ["ln1", "ln2"] {
                let name = format!("{lp}.{ln}.g");
                let t = model.fp_tensor(&name).ok_or_else(|| {
                    anyhow!("packed model missing '{name}' (host engine serves the llama family)")
                })?;
                if t.shape() != [d].as_slice() {
                    bail!("'{name}' is {:?}, expected [{d}]", t.shape());
                }
            }
            for (p, rows, cols) in [
                ("attn.q", d, d),
                ("attn.k", d, d),
                ("attn.v", d, d),
                ("attn.o", d, d),
                ("mlp.gate", geom.d_ff, d),
                ("mlp.up", geom.d_ff, d),
                ("mlp.down", d, geom.d_ff),
            ] {
                let prefix = format!("{lp}.{p}");
                let dims = if let Some(m) = model.matrix(&prefix) {
                    (m.rows, m.cols)
                } else if let Some(t) = model.fp_tensor(&format!("{prefix}.w")) {
                    t.dims2()?
                } else {
                    bail!("packed model missing projection '{prefix}'");
                };
                if dims != (rows, cols) {
                    bail!("projection '{prefix}' is {dims:?}, geometry wants ({rows}, {cols})");
                }
            }
            layer_names.push(LayerNames::new(i));
        }
        let freqs = rope_freqs(geom.head_dim());
        // Snapshot the base task's scales/zeros of every packed
        // projection: apply_adapter restores these on projections an
        // adapter does not cover.
        let base_sz = model
            .prefixes()
            .into_iter()
            .filter_map(|p| {
                model.matrix(&p).map(|m| (p.clone(), m.scales.clone(), m.zeros.clone()))
            })
            .collect();
        Ok(Engine {
            model,
            geom,
            threads: threads.max(1),
            freqs,
            head_name,
            layer_names,
            base_sz,
            swapped_s: std::collections::HashSet::new(),
            swapped_z: std::collections::HashSet::new(),
            scratch: Scratch::default(),
        })
    }

    pub fn geom(&self) -> &ModelGeom {
        &self.geom
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Bytes of bit-packed code storage being served (never changes over
    /// the engine's lifetime — adapters only swap f32 scale/zero tensors).
    pub fn packed_bytes(&self) -> usize {
        self.model.packed_bytes()
    }

    /// A fresh ring-buffer K/V sequence sized for this model with the
    /// given window — the default backend (`--kv-pages 0`). Paged
    /// sequences come from [`super::kvpage::PagePool::admit_seq`]
    /// instead and wrap as [`KvSeq::Paged`]; the engine drives both
    /// through the same [`KvSeq`] surface.
    pub fn new_cache(&self, capacity: usize) -> KvSeq {
        KvSeq::Ring(KvCache::new(self.geom.n_layers, self.geom.d_model, capacity))
    }

    /// Coverage gaps of `adapter` against this engine's packed
    /// projections — the strict-coverage registration check
    /// (`BatcherConfig::strict_coverage`), shared with the xla
    /// coordinator via [`super::types::adapter_coverage_gaps`]. Returns
    /// the missing tensor names; empty means full coverage.
    /// [`Engine::apply_adapter`] itself stays partial-tolerant —
    /// uncovered projections revert to base scales.
    pub fn adapter_coverage_gaps(&self, adapter: &Checkpoint) -> Vec<String> {
        super::types::adapter_coverage_gaps(&self.model.prefixes(), adapter)
    }

    /// PEQA task switch: overlay an adapter's scale/zero tensors onto the
    /// packed projections. Only `{prefix}.s` / `{prefix}.z` tensors are
    /// accepted and only the f32 scale/zero tensors move — the packed
    /// integer codes are immutable. Every packed projection the adapter
    /// does **not** cover is restored to the construction-time base
    /// scales/zeros, so switching from task A to a partial-coverage task
    /// B never serves B with A's residue: the engine state after a swap
    /// depends only on the adapter applied, never on swap history.
    /// Validates everything before mutating anything, so a failed swap
    /// leaves the engine unchanged. Returns the number of adapter
    /// tensors applied (restores are not counted).
    pub fn apply_adapter(&mut self, adapter: &Checkpoint) -> Result<usize> {
        let mut plan: Vec<(String, bool, &Tensor)> = Vec::with_capacity(adapter.len());
        for (name, t) in adapter.iter() {
            let (prefix, is_scale) = if let Some(p) = name.strip_suffix(".s") {
                (p, true)
            } else if let Some(p) = name.strip_suffix(".z") {
                (p, false)
            } else {
                bail!(
                    "scale-swap adapter may only carry .s/.z tensors of packed \
                     projections, got '{name}'"
                );
            };
            let m = self
                .model
                .matrix(prefix)
                .ok_or_else(|| anyhow!("adapter tensor '{name}' covers no packed projection"))?;
            if t.shape() != m.scales.shape() {
                bail!(
                    "adapter tensor '{name}': shape {:?} != projection's {:?}",
                    t.shape(),
                    m.scales.shape()
                );
            }
            plan.push((prefix.to_string(), is_scale, t));
        }
        let n = plan.len();
        let Engine { model, base_sz, swapped_s, swapped_z, .. } = self;
        for (prefix, is_scale, t) in &plan {
            // peqa-lint: allow(panic-free-paths) -- the same prefix
            // resolved a matrix in the validation loop above; a miss
            // here is a code bug, and erroring out mid-loop would leave
            // a half-applied adapter.
            let m = model.matrix_mut(prefix).expect("validated above");
            if *is_scale {
                m.scales = (*t).clone();
            } else {
                m.zeros = (*t).clone();
            }
        }
        // Residue fix: every (s, z) the PREVIOUS adapter touched that this
        // adapter leaves untouched reverts to the base snapshot taken at
        // engine construction. Projections outside both coverage sets
        // already hold base values, so the restore cost tracks the
        // adapters' coverage, not the model size.
        let covered_s: std::collections::HashSet<String> =
            plan.iter().filter(|p| p.1).map(|p| p.0.clone()).collect();
        let covered_z: std::collections::HashSet<String> =
            plan.iter().filter(|p| !p.1).map(|p| p.0.clone()).collect();
        for (prefix, s0, z0) in base_sz.iter() {
            let stale_s = swapped_s.contains(prefix) && !covered_s.contains(prefix);
            let stale_z = swapped_z.contains(prefix) && !covered_z.contains(prefix);
            if stale_s || stale_z {
                // peqa-lint: allow(panic-free-paths) -- `base_sz` keys
                // were snapshotted from this very model at construction;
                // a miss is a code bug, and bailing mid-restore would
                // strand the previous task's residue.
                let m = model.matrix_mut(prefix).expect("snapshot taken from this model");
                if stale_s {
                    m.scales = s0.clone();
                }
                if stale_z {
                    m.zeros = z0.clone();
                }
            }
        }
        *swapped_s = covered_s;
        *swapped_z = covered_z;
        Ok(n)
    }

    /// Feed a block of tokens of ONE sequence through the model,
    /// appending K/V to `cache`, and return the logits of the last
    /// position (`vocab` floats). Used both for prompt prefill (the
    /// projections run batched over the whole block through the fused
    /// GEMM) and — with a single token — for unbatched decode.
    pub fn prefill(&mut self, tokens: &[u32], cache: &mut KvSeq) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            bail!("prefill needs at least one token");
        }
        let mut caches = [cache];
        self.forward_multi(&[tokens], &mut caches)
    }

    /// Cross-request prefill batching: feed the prompt blocks of SEVERAL
    /// sequences (each with its own cache) through the model, with every
    /// projection batched over the concatenated token rows of all
    /// prompts in one fused GEMM call. Returns the concatenated
    /// last-position logits, `(prompts.len() · vocab)`. Per-sequence
    /// rows are bitwise identical to prefilling each prompt alone —
    /// grouping is a throughput decision, never a numerics one.
    pub fn prefill_batch(
        &mut self,
        prompts: &[&[u32]],
        caches: &mut [&mut KvSeq],
    ) -> Result<Vec<f32>> {
        if prompts.iter().any(|p| p.is_empty()) {
            bail!("prefill_batch needs at least one token per prompt");
        }
        self.forward_multi(prompts, caches)
    }

    /// Advance `tokens.len()` sequences by one token each (continuous
    /// batching decode step). Returns the concatenated logits rows
    /// `(batch · vocab)`. Per-sequence results are bitwise independent of
    /// the batch composition: row `i` equals a batch-1 call for that
    /// sequence alone.
    pub fn decode_batch(
        &mut self,
        tokens: &[u32],
        caches: &mut [&mut KvSeq],
    ) -> Result<Vec<f32>> {
        let seqs: Vec<&[u32]> = tokens.chunks(1).collect();
        self.forward_multi(&seqs, caches)
    }

    /// The shared multi-sequence forward: `seqs[i]` appends its tokens to
    /// `caches[i]`; returns the last-position logits row per sequence.
    /// All dense work (norms, projections, LM head) runs batched over the
    /// concatenated rows of every sequence; rotary/cache/attention run
    /// per sequence token-by-token (each over its own cache only), which
    /// is what makes every row bitwise independent of how sequences are
    /// grouped into calls.
    fn forward_multi(
        &mut self,
        seqs: &[&[u32]],
        caches: &mut [&mut KvSeq],
    ) -> Result<Vec<f32>> {
        let n_seqs = seqs.len();
        if n_seqs != caches.len() {
            bail!("forward: {} sequences but {} caches", n_seqs, caches.len());
        }
        if n_seqs == 0 {
            return Ok(Vec::new());
        }
        let Engine { model, geom, threads, freqs, head_name, layer_names, scratch, .. } = self;
        let (geom, threads, head_name) = (*geom, *threads, *head_name);
        // Shared-borrow view so the attention worker closure stays `Fn`.
        let freqs: &[f32] = freqs;
        let d = geom.d_model;
        let (hh, hd) = (geom.n_heads, geom.head_dim());
        scratch.spans.clear();
        scratch.spans.extend(seqs.iter().map(|s| s.len()));
        let m: usize = scratch.spans.iter().sum();

        // Embedding gather over the concatenated token rows.
        ensure(&mut scratch.x, m * d);
        let ed = model
            .fp_tensor("embed")
            .ok_or_else(|| anyhow!("packed model missing fp tensor 'embed'"))?
            .data();
        let mut row = 0usize;
        for seq in seqs {
            for &tok in *seq {
                let tok = tok as usize;
                if tok >= geom.vocab {
                    bail!("token id {tok} out of vocab {}", geom.vocab);
                }
                scratch.x[row * d..(row + 1) * d].copy_from_slice(&ed[tok * d..(tok + 1) * d]);
                row += 1;
            }
        }

        for layer in 0..geom.n_layers {
            let ln = &layer_names[layer];
            // Pre-norm + the three attention input projections, batched
            // over every row of every sequence.
            let g1 = model
                .fp_tensor(&ln.ln1)
                .ok_or_else(|| anyhow!("packed model missing fp tensor '{}'", ln.ln1))?
                .data();
            rms_norm_rows_into(&scratch.x[..m * d], g1, m, d, &mut scratch.h, None);
            proj_into(model, threads, &ln.q, &scratch.h[..m * d], &scratch.spans, &mut scratch.q, &mut scratch.proj)?;
            proj_into(model, threads, &ln.k, &scratch.h[..m * d], &scratch.spans, &mut scratch.k, &mut scratch.proj)?;
            proj_into(model, threads, &ln.v, &scratch.h[..m * d], &scratch.spans, &mut scratch.v, &mut scratch.proj)?;
            ensure(&mut scratch.ctx, m * d);
            // Rotary + cache append + attention, sharded across batch
            // rows: sequences are mutually independent (each attends
            // only over its own cache), so contiguous sequence ranges go
            // to scoped workers. Each worker owns disjoint q/k/ctx row
            // slabs, its own caches and its own AttnScratch, and runs
            // exactly the single-worker code per sequence — results are
            // bitwise identical at any worker count.
            let workers = threads.min(n_seqs).max(1);
            if scratch.attn.len() < workers {
                scratch.attn.resize_with(workers, AttnScratch::default);
            }
            {
                // Each carve peels the chunk's sequences plus their
                // (ragged) activation row slabs off the remainders; every
                // chunk runs exactly the single-worker code per sequence.
                let mut seqs_rem: &[&[u32]] = seqs;
                let mut caches_rem: &mut [&mut KvSeq] = &mut *caches;
                let mut q_rem: &mut [f32] = &mut scratch.q[..m * d];
                let mut k_rem: &mut [f32] = &mut scratch.k[..m * d];
                let mut ctx_rem: &mut [f32] = &mut scratch.ctx[..m * d];
                let v_all: &[f32] = &scratch.v[..m * d];
                let mut attn_rem: &mut [AttnScratch] = &mut scratch.attn[..workers];
                let mut row0 = 0usize;
                blocks::shard_chunks(
                    n_seqs,
                    workers,
                    |_, take| {
                        let rows: usize = seqs_rem[..take].iter().map(|s| s.len()).sum();
                        let (seq_c, sr) = seqs_rem.split_at(take);
                        seqs_rem = sr;
                        let (cache_c, cr) =
                            std::mem::take(&mut caches_rem).split_at_mut(take);
                        caches_rem = cr;
                        let (q_c, qr) = std::mem::take(&mut q_rem).split_at_mut(rows * d);
                        q_rem = qr;
                        let (k_c, kr) = std::mem::take(&mut k_rem).split_at_mut(rows * d);
                        k_rem = kr;
                        let (ctx_c, xr) = std::mem::take(&mut ctx_rem).split_at_mut(rows * d);
                        ctx_rem = xr;
                        let (attn_c, ar) = std::mem::take(&mut attn_rem).split_at_mut(1);
                        attn_rem = ar;
                        let v_c = &v_all[row0 * d..(row0 + rows) * d];
                        row0 += rows;
                        (seq_c, cache_c, q_c, k_c, v_c, ctx_c, attn_c)
                    },
                    |_, _, (seq_c, cache_c, q_c, k_c, v_c, ctx_c, attn_c)| {
                        attend_seq_chunk(
                            freqs,
                            hh,
                            hd,
                            d,
                            layer,
                            seq_c,
                            cache_c,
                            q_c,
                            k_c,
                            v_c,
                            ctx_c,
                            &mut attn_c[0],
                        );
                    },
                );
            }
            // Attention output + residual, then the SwiGLU MLP + residual.
            proj_into(model, threads, &ln.o, &scratch.ctx[..m * d], &scratch.spans, &mut scratch.o, &mut scratch.proj)?;
            for (xv, ov) in scratch.x[..m * d].iter_mut().zip(&scratch.o[..m * d]) {
                *xv += ov;
            }
            let g2 = model
                .fp_tensor(&ln.ln2)
                .ok_or_else(|| anyhow!("packed model missing fp tensor '{}'", ln.ln2))?
                .data();
            rms_norm_rows_into(&scratch.x[..m * d], g2, m, d, &mut scratch.h, None);
            proj_into(model, threads, &ln.gate, &scratch.h[..m * d], &scratch.spans, &mut scratch.gate, &mut scratch.proj)?;
            proj_into(model, threads, &ln.up, &scratch.h[..m * d], &scratch.spans, &mut scratch.up, &mut scratch.proj)?;
            let mf = m * geom.d_ff;
            blocks::swiglu_rows_into(&scratch.gate[..mf], &scratch.up[..mf], mf, &mut scratch.act);
            proj_into(model, threads, &ln.down, &scratch.act[..mf], &scratch.spans, &mut scratch.down, &mut scratch.proj)?;
            for (xv, dv) in scratch.x[..m * d].iter_mut().zip(&scratch.down[..m * d]) {
                *xv += dv;
            }
        }

        // Gather each sequence's last position, mark the tokens appended,
        // and run final norm + LM head batched over the gathered rows.
        ensure(&mut scratch.last, n_seqs * d);
        let mut r0 = 0usize;
        for (si, seq) in seqs.iter().enumerate() {
            let r = r0 + seq.len() - 1;
            scratch.last[si * d..(si + 1) * d].copy_from_slice(&scratch.x[r * d..(r + 1) * d]);
            r0 += seq.len();
        }
        for (cache, seq) in caches.iter_mut().zip(seqs) {
            cache.advance(seq.len());
        }
        let gf = model
            .fp_tensor("final_norm.g")
            .ok_or_else(|| anyhow!("packed model missing fp tensor 'final_norm.g'"))?
            .data();
        rms_norm_rows_into(&scratch.last[..n_seqs * d], gf, n_seqs, d, &mut scratch.h, None);
        let head = model
            .fp_tensor(head_name)
            .ok_or_else(|| anyhow!("packed model missing fp tensor '{head_name}'"))?;
        let mut logits = vec![0.0f32; n_seqs * geom.vocab];
        blocks::dense_rows_core(
            head,
            &scratch.h[..n_seqs * d],
            n_seqs,
            &mut logits,
            crate::quant::simd::active(),
            &mut scratch.proj.kernel,
        );
        Ok(logits)
    }
}

/// Parity baseline: full causal forward over a *dense* fp checkpoint
/// (the dequantized view of the packed model) using the seed's
/// single-threaded `matmul_naive` for every projection. Returns the
/// `(T, vocab)` logits tensor. No KV cache, no packed codes — this is
/// the "unpack → dequantize → naive matmul" path the fused engine is
/// verified against (decode parity ≤ 1e-4).
pub fn reference_forward(fp: &Checkpoint, geom: &ModelGeom, tokens: &[u32]) -> Result<Tensor> {
    reference_forward_windowed(fp, geom, tokens, usize::MAX)
}

/// [`reference_forward`] restricted to sliding-window attention: each
/// query position attends only to the most recent `window` positions
/// (itself included) — the dense mirror of a [`KvCache`] whose ring
/// capacity is `window`, used to pin ring-wrap prefill/decode parity.
pub fn reference_forward_windowed(
    fp: &Checkpoint,
    geom: &ModelGeom,
    tokens: &[u32],
    window: usize,
) -> Result<Tensor> {
    let t_len = tokens.len();
    if t_len == 0 {
        bail!("reference_forward needs at least one token");
    }
    let window = window.max(1);
    let d = geom.d_model;
    let (hh, hd) = (geom.n_heads, geom.head_dim());
    let half = hd / 2;
    let embed = fp.req("embed")?;
    let mut x = vec![0.0f32; t_len * d];
    for (ti, &tok) in tokens.iter().enumerate() {
        x[ti * d..(ti + 1) * d]
            .copy_from_slice(&embed.data()[tok as usize * d..(tok as usize + 1) * d]);
    }
    let freqs = rope_freqs(hd);
    let rope = |row: &mut [f32], pos: usize| {
        let p = pos as f32;
        for h in 0..hh {
            let s = &mut row[h * hd..(h + 1) * hd];
            for i in 0..half {
                let (sin, cos) = (p * freqs[i]).sin_cos();
                let (x1, x2) = (s[i], s[i + half]);
                s[i] = x1 * cos - x2 * sin;
                s[i + half] = x1 * sin + x2 * cos;
            }
        }
    };
    let proj = |name: String, h: &[f32]| -> Result<Vec<f32>> {
        let w = fp.req(&name)?;
        let (_, cin) = w.dims2()?;
        let ht = Tensor::new(&[h.len() / cin, cin], h.to_vec());
        Ok(ht.matmul_naive(&w.t())?.into_data())
    };
    let inv = 1.0 / (hd as f32).sqrt();
    for layer in 0..geom.n_layers {
        let lp = format!("layers.{layer}");
        let h = rms_norm_rows(&x, fp.req(&format!("{lp}.ln1.g"))?.data(), t_len, d);
        let mut q = proj(format!("{lp}.attn.q.w"), &h)?;
        let mut k = proj(format!("{lp}.attn.k.w"), &h)?;
        let v = proj(format!("{lp}.attn.v.w"), &h)?;
        for ti in 0..t_len {
            rope(&mut q[ti * d..(ti + 1) * d], ti);
            rope(&mut k[ti * d..(ti + 1) * d], ti);
        }
        let mut ctx = vec![0.0f32; t_len * d];
        for ti in 0..t_len {
            let start = (ti + 1).saturating_sub(window);
            for hi in 0..hh {
                let qh = &q[ti * d + hi * hd..ti * d + (hi + 1) * hd];
                let mut scores = vec![0.0f32; ti + 1 - start];
                let mut maxs = f32::NEG_INFINITY;
                for (j, sc) in scores.iter_mut().enumerate() {
                    let p = start + j;
                    let kh = &k[p * d + hi * hd..p * d + (hi + 1) * hd];
                    let mut dot = 0.0f32;
                    for t in 0..hd {
                        dot += qh[t] * kh[t];
                    }
                    *sc = dot * inv;
                    if *sc > maxs {
                        maxs = *sc;
                    }
                }
                let mut denom = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxs).exp();
                    denom += *sc;
                }
                let cxh = &mut ctx[ti * d + hi * hd..ti * d + (hi + 1) * hd];
                for (j, &w) in scores.iter().enumerate() {
                    let p = start + j;
                    let pw = w / denom;
                    let vh = &v[p * d + hi * hd..p * d + (hi + 1) * hd];
                    for t in 0..hd {
                        cxh[t] += pw * vh[t];
                    }
                }
            }
        }
        let o = proj(format!("{lp}.attn.o.w"), &ctx)?;
        for (xv, ov) in x.iter_mut().zip(&o) {
            *xv += ov;
        }
        let h2 = rms_norm_rows(&x, fp.req(&format!("{lp}.ln2.g"))?.data(), t_len, d);
        let gate = proj(format!("{lp}.mlp.gate.w"), &h2)?;
        let up = proj(format!("{lp}.mlp.up.w"), &h2)?;
        let mut act = vec![0.0f32; gate.len()];
        for j in 0..gate.len() {
            act[j] = silu(gate[j]) * up[j];
        }
        let down = proj(format!("{lp}.mlp.down.w"), &act)?;
        for (xv, dv) in x.iter_mut().zip(&down) {
            *xv += dv;
        }
    }
    let xn = rms_norm_rows(&x, fp.req("final_norm.g")?.data(), t_len, d);
    let head = match fp.get("lm_head") {
        Some(h) => h,
        None => embed,
    };
    Tensor::new(&[t_len, d], xn).matmul_naive(&head.t())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins_ties() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
        // NaN never compares greater, so it neither wins nor crashes:
        // a leading NaN stays "best", an interior NaN is skipped.
        assert_eq!(argmax(&[f32::NAN, 2.0, 2.0]), 0);
        assert_eq!(argmax(&[1.0, f32::NAN, 3.0]), 2);
    }

    #[test]
    fn topk_sampling_is_seeded_and_respects_k() {
        let logits = vec![0.1, 5.0, 4.0, -2.0, 3.0];
        // k = 1 degenerates to greedy regardless of the rng.
        let mut rng = Pcg32::new(1);
        for _ in 0..8 {
            assert_eq!(sample(&logits, Sampling::TopK { k: 1, temperature: 1.0 }, &mut rng), 1);
        }
        // Same seed → same draws; all draws land in the top-3 set.
        let draws = |seed: u64| -> Vec<u32> {
            let mut rng = Pcg32::new(seed);
            (0..32)
                .map(|_| sample(&logits, Sampling::TopK { k: 3, temperature: 1.0 }, &mut rng))
                .collect()
        };
        let a = draws(7);
        assert_eq!(a, draws(7));
        assert!(a.iter().all(|t| [1u32, 2, 4].contains(t)), "{a:?}");
        assert_ne!(a, draws(8));
    }

    #[test]
    fn topk_sampling_survives_nan_logits() {
        // NaN mixed into the row: the comparator must stay a total order
        // (the old partial_cmp fallback could panic select_nth/sort) and
        // draws must never land on a NaN index.
        let logits = vec![f32::NAN, 1.0, f32::NAN, 5.0, 2.0, f32::NAN, 0.5];
        let mut rng = Pcg32::new(3);
        for _ in 0..64 {
            let t = sample(&logits, Sampling::TopK { k: 4, temperature: 1.0 }, &mut rng);
            assert!([1u32, 3, 4, 6].contains(&t), "drew NaN index {t}");
        }
        // k larger than the non-NaN count: NaN candidates weigh zero.
        for _ in 0..32 {
            let t = sample(&logits, Sampling::TopK { k: 7, temperature: 0.7 }, &mut rng);
            assert!(!logits[t as usize].is_nan(), "drew NaN index {t}");
        }
        // All-NaN row: deterministic lowest index, no panic.
        let all_nan = vec![f32::NAN; 5];
        for _ in 0..4 {
            assert_eq!(sample(&all_nan, Sampling::TopK { k: 3, temperature: 1.0 }, &mut rng), 0);
        }
        // NaN rows under greedy stay panic-free too.
        assert_eq!(sample(&all_nan, Sampling::Greedy, &mut rng), 0);
    }

    #[test]
    fn geometry_validation() {
        let ok = ModelGeom { vocab: 16, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 12 };
        assert!(ok.validated().is_ok());
        assert_eq!(ok.head_dim(), 4);
        let odd_head = ModelGeom { n_heads: 4, ..ok }; // head_dim 2 ok
        assert!(odd_head.validated().is_ok());
        let bad_div = ModelGeom { n_heads: 3, ..ok };
        assert!(bad_div.validated().is_err());
        let odd = ModelGeom { d_model: 6, n_heads: 2, ..ok }; // head_dim 3
        assert!(odd.validated().is_err());
        let zero = ModelGeom { n_layers: 0, ..ok };
        assert!(zero.validated().is_err());
    }
}
