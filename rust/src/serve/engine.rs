//! Host autoregressive decode engine over the fused packed kernel layer.
//!
//! The paper's deployment claim is that a PEQA model *serves* in its
//! quantized form: sub-4-bit integer codes stay bit-packed in memory,
//! every block projection runs through the fused quantized GEMM
//! (`quant::kernels::PackedMatrix::matmul_t` and its decode entry points
//! `matvec_t` / `matmul_t_rows`), and a task is nothing but a set of f32
//! scale/zero vectors. This module is that claim executed on a plain
//! host, no `xla` feature required:
//!
//! * [`Engine`] — llama-family transformer forward from a
//!   [`PackedModel`]: embedding gather, RMSNorm, rotary positions,
//!   causal attention over a per-sequence [`KvCache`], SwiGLU MLP,
//!   fp LM head. [`Engine::prefill`] consumes a block of prompt tokens
//!   (projections batched over the block through the fused GEMM),
//!   [`Engine::decode_batch`] advances several *sequences* one token
//!   each. Per-sequence math is independent of batch composition and of
//!   the worker-thread count, so greedy decode is **bit-identical**
//!   across batch sizes and across `PEQA_THREADS` settings.
//! * [`Engine::apply_adapter`] — PEQA task switching: replaces only the
//!   f32 scale/zero tensors of adapter-covered projections. The packed
//!   code buffers are never touched, cloned, or re-packed.
//! * [`Sampling`] / [`sample`] — greedy argmax and seeded top-k.
//! * [`reference_forward`] — the parity baseline: full causal attention
//!   over *dense dequantized* weights via the seed's `matmul_naive`.
//!   The engine must agree with it to ≤ 1e-4 (tests/serve_host.rs).
//!
//! Model geometry comes from [`ModelGeom`]: either a typed artifact
//! meta.json ([`ModelGeom::from_artifact`]) or inferred from the packed
//! tensors themselves ([`ModelGeom::infer`]; only `n_heads` cannot be
//! recovered from shapes).

use anyhow::{anyhow, bail, Result};

use super::kvcache::KvCache;
use crate::model::{Checkpoint, PackedModel};
use crate::runtime::ArtifactMeta;
use crate::tensor::Tensor;
use crate::util::Pcg32;

const RMS_EPS: f32 = 1e-6;

/// Static transformer geometry of a served model (llama family:
/// RMSNorm + rotary + SwiGLU — the architecture the paper quantizes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelGeom {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl ModelGeom {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    fn validated(self) -> Result<ModelGeom> {
        if self.vocab == 0 || self.d_model == 0 || self.n_layers == 0 || self.d_ff == 0 {
            bail!("degenerate model geometry {self:?}");
        }
        if self.n_heads == 0 || self.d_model % self.n_heads != 0 {
            bail!("n_heads {} must divide d_model {}", self.n_heads, self.d_model);
        }
        if self.head_dim() % 2 != 0 {
            bail!("rotary positions need an even head_dim, got {}", self.head_dim());
        }
        Ok(self)
    }

    /// Geometry from a typed artifact meta.json (the canonical source —
    /// python/compile is the single source of truth for model shape).
    pub fn from_artifact(meta: &ArtifactMeta) -> Result<ModelGeom> {
        let m = meta
            .model
            .as_ref()
            .ok_or_else(|| anyhow!("artifact '{}' carries no model geometry", meta.name))?;
        if m.family != "llama" {
            bail!(
                "host engine serves the llama family (RMSNorm/rope/SwiGLU); \
                 artifact '{}' is '{}'",
                meta.name,
                m.family
            );
        }
        ModelGeom {
            vocab: m.vocab,
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            d_ff: m.d_ff,
        }
        .validated()
    }

    /// Infer geometry from a packed model's tensors. `n_heads` cannot be
    /// recovered from shapes and must be supplied by the caller.
    pub fn infer(model: &PackedModel, n_heads: usize) -> Result<ModelGeom> {
        let embed = model
            .fp_tensor("embed")
            .ok_or_else(|| anyhow!("packed model has no 'embed' tensor"))?;
        let (vocab, d_model) = embed.dims2()?;
        let mut n_layers = 0usize;
        for name in model.tensor_names() {
            if let Some(rest) = name.strip_prefix("layers.") {
                if let Some(i) = rest.split('.').next().and_then(|s| s.parse::<usize>().ok()) {
                    n_layers = n_layers.max(i + 1);
                }
            }
        }
        if n_layers == 0 {
            bail!("packed model has no 'layers.*' tensors — nothing to serve");
        }
        let d_ff = if let Some(m) = model.matrix("layers.0.mlp.gate") {
            m.rows
        } else if let Some(t) = model.fp_tensor("layers.0.mlp.gate.w") {
            t.dims2()?.0
        } else {
            bail!(
                "packed model has no 'layers.0.mlp.gate' projection \
                 (host engine serves the llama family)"
            );
        };
        ModelGeom { vocab, d_model, n_layers, n_heads, d_ff }.validated()
    }
}

/// Token selection policy for the decode loop.
#[derive(Clone, Copy, Debug)]
pub enum Sampling {
    /// Deterministic argmax (first index wins ties) — the mode the
    /// bit-identical batch/thread invariance guarantees apply to.
    Greedy,
    /// Sample from the `k` highest logits at `temperature`, drawn from a
    /// seeded [`Pcg32`] stream (deterministic given the stream order).
    TopK { k: usize, temperature: f32 },
}

/// Select the next token from one logits row.
pub fn sample(logits: &[f32], sampling: Sampling, rng: &mut Pcg32) -> u32 {
    match sampling {
        Sampling::Greedy => argmax(logits),
        Sampling::TopK { k, temperature } => {
            let k = k.max(1).min(logits.len());
            // Descending by logit, ties broken by index — a total order,
            // so partitioning the top k and then sorting only those k
            // gives exactly the full-sort prefix at O(V) instead of
            // O(V log V) per sampled token.
            let cmp = |a: &usize, b: &usize| {
                logits[*b]
                    .partial_cmp(&logits[*a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(b))
            };
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, cmp);
                idx.truncate(k);
            }
            idx.sort_by(cmp);
            let t = temperature.max(1e-6);
            let top = logits[idx[0]];
            let ws: Vec<f32> = idx.iter().map(|&i| ((logits[i] - top) / t).exp()).collect();
            let total: f32 = ws.iter().sum();
            let mut r = rng.f32() * total;
            for (j, &w) in ws.iter().enumerate() {
                r -= w;
                if r <= 0.0 {
                    return idx[j] as u32;
                }
            }
            idx[k - 1] as u32
        }
    }
}

/// First-index argmax (NaN-safe: comparisons against NaN keep the best).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for i in 1..logits.len() {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    best as u32
}

/// Host decode engine over a [`PackedModel`] (see module docs).
pub struct Engine {
    model: PackedModel,
    geom: ModelGeom,
    threads: usize,
    /// Rotary frequency table, head_dim/2 entries.
    freqs: Vec<f32>,
    /// "lm_head" or "embed" (tied head).
    head_name: &'static str,
    /// Per-layer tensor names resolved once at construction, so the
    /// per-token decode loop does no string formatting.
    layer_names: Vec<LayerNames>,
}

struct LayerNames {
    ln1: String,
    ln2: String,
    q: String,
    k: String,
    v: String,
    o: String,
    gate: String,
    up: String,
    down: String,
}

impl Engine {
    /// Validate that `model` carries a complete llama-family layout for
    /// `geom` and wrap it for serving. `threads` pins the fused-kernel
    /// worker count (results are bit-identical for any value).
    pub fn from_packed(model: PackedModel, geom: ModelGeom, threads: usize) -> Result<Engine> {
        let geom = geom.validated()?;
        let d = geom.d_model;
        let embed = model
            .fp_tensor("embed")
            .ok_or_else(|| anyhow!("packed model missing fp tensor 'embed'"))?;
        if embed.shape() != [geom.vocab, d].as_slice() {
            bail!("'embed' is {:?}, geometry wants [{}, {d}]", embed.shape(), geom.vocab);
        }
        let head_name = if let Some(h) = model.fp_tensor("lm_head") {
            if h.shape() != [geom.vocab, d].as_slice() {
                bail!("'lm_head' is {:?}, geometry wants [{}, {d}]", h.shape(), geom.vocab);
            }
            "lm_head"
        } else {
            "embed" // tied head
        };
        let fg = model
            .fp_tensor("final_norm.g")
            .ok_or_else(|| anyhow!("packed model missing 'final_norm.g'"))?;
        if fg.shape() != [d].as_slice() {
            bail!("'final_norm.g' is {:?}, expected [{d}]", fg.shape());
        }
        let mut layer_names = Vec::with_capacity(geom.n_layers);
        for i in 0..geom.n_layers {
            let lp = format!("layers.{i}");
            for ln in ["ln1", "ln2"] {
                let name = format!("{lp}.{ln}.g");
                let t = model.fp_tensor(&name).ok_or_else(|| {
                    anyhow!("packed model missing '{name}' (host engine serves the llama family)")
                })?;
                if t.shape() != [d].as_slice() {
                    bail!("'{name}' is {:?}, expected [{d}]", t.shape());
                }
            }
            for (p, rows, cols) in [
                ("attn.q", d, d),
                ("attn.k", d, d),
                ("attn.v", d, d),
                ("attn.o", d, d),
                ("mlp.gate", geom.d_ff, d),
                ("mlp.up", geom.d_ff, d),
                ("mlp.down", d, geom.d_ff),
            ] {
                let prefix = format!("{lp}.{p}");
                let dims = if let Some(m) = model.matrix(&prefix) {
                    (m.rows, m.cols)
                } else if let Some(t) = model.fp_tensor(&format!("{prefix}.w")) {
                    t.dims2()?
                } else {
                    bail!("packed model missing projection '{prefix}'");
                };
                if dims != (rows, cols) {
                    bail!("projection '{prefix}' is {dims:?}, geometry wants ({rows}, {cols})");
                }
            }
            layer_names.push(LayerNames {
                ln1: format!("{lp}.ln1.g"),
                ln2: format!("{lp}.ln2.g"),
                q: format!("{lp}.attn.q"),
                k: format!("{lp}.attn.k"),
                v: format!("{lp}.attn.v"),
                o: format!("{lp}.attn.o"),
                gate: format!("{lp}.mlp.gate"),
                up: format!("{lp}.mlp.up"),
                down: format!("{lp}.mlp.down"),
            });
        }
        let half = geom.head_dim() / 2;
        let freqs = (0..half)
            .map(|i| 10000.0f32.powf(-(i as f32) / half as f32))
            .collect();
        Ok(Engine { model, geom, threads: threads.max(1), freqs, head_name, layer_names })
    }

    pub fn geom(&self) -> &ModelGeom {
        &self.geom
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Bytes of bit-packed code storage being served (never changes over
    /// the engine's lifetime — adapters only swap f32 scale/zero tensors).
    pub fn packed_bytes(&self) -> usize {
        self.model.packed_bytes()
    }

    /// A fresh K/V cache sized for this model with the given window.
    pub fn new_cache(&self, capacity: usize) -> KvCache {
        KvCache::new(self.geom.n_layers, self.geom.d_model, capacity)
    }

    /// PEQA task switch: overlay an adapter's scale/zero tensors onto the
    /// packed projections. Only `{prefix}.s` / `{prefix}.z` tensors are
    /// accepted and only the f32 scale/zero tensors move — the packed
    /// integer codes are immutable. Validates everything before mutating
    /// anything, so a failed swap leaves the engine unchanged. Returns
    /// the number of tensors swapped.
    pub fn apply_adapter(&mut self, adapter: &Checkpoint) -> Result<usize> {
        let mut plan: Vec<(String, bool, &Tensor)> = Vec::with_capacity(adapter.len());
        for (name, t) in adapter.iter() {
            let (prefix, is_scale) = if let Some(p) = name.strip_suffix(".s") {
                (p, true)
            } else if let Some(p) = name.strip_suffix(".z") {
                (p, false)
            } else {
                bail!(
                    "scale-swap adapter may only carry .s/.z tensors of packed \
                     projections, got '{name}'"
                );
            };
            let m = self
                .model
                .matrix(prefix)
                .ok_or_else(|| anyhow!("adapter tensor '{name}' covers no packed projection"))?;
            if t.shape() != m.scales.shape() {
                bail!(
                    "adapter tensor '{name}': shape {:?} != projection's {:?}",
                    t.shape(),
                    m.scales.shape()
                );
            }
            plan.push((prefix.to_string(), is_scale, t));
        }
        let n = plan.len();
        for (prefix, is_scale, t) in plan {
            let m = self.model.matrix_mut(&prefix).expect("validated above");
            if is_scale {
                m.scales = t.clone();
            } else {
                m.zeros = t.clone();
            }
        }
        Ok(n)
    }

    /// Feed a block of tokens of ONE sequence through the model,
    /// appending K/V to `cache`, and return the logits of the last
    /// position (`vocab` floats). Used both for prompt prefill (the
    /// projections run batched over the whole block through the fused
    /// GEMM) and — with a single token — for unbatched decode.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache) -> Result<Vec<f32>> {
        let t_new = tokens.len();
        if t_new == 0 {
            bail!("prefill needs at least one token");
        }
        let d = self.geom.d_model;
        let base = cache.pos();
        let mut x = self.gather_embed(tokens)?;
        for layer in 0..self.geom.n_layers {
            let ln = &self.layer_names[layer];
            let (mut q, mut k, v) = self.qkv(ln, &x, t_new)?;
            let mut ctx = vec![0.0f32; t_new * d];
            for ti in 0..t_new {
                let abs = base + ti;
                self.rope_row(&mut q[ti * d..(ti + 1) * d], abs);
                self.rope_row(&mut k[ti * d..(ti + 1) * d], abs);
                cache.write(layer, abs, &k[ti * d..(ti + 1) * d], &v[ti * d..(ti + 1) * d]);
                self.attend_one(
                    cache,
                    layer,
                    abs,
                    &q[ti * d..(ti + 1) * d],
                    &mut ctx[ti * d..(ti + 1) * d],
                );
            }
            self.finish_block(ln, &mut x, &ctx, t_new)?;
        }
        cache.advance(t_new);
        self.head_logits(&x[(t_new - 1) * d..], 1)
    }

    /// Advance `tokens.len()` sequences by one token each (continuous
    /// batching decode step). Returns the concatenated logits rows
    /// `(batch · vocab)`. Per-sequence results are bitwise independent of
    /// the batch composition: row `i` equals a batch-1 call for that
    /// sequence alone.
    pub fn decode_batch(
        &self,
        tokens: &[u32],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<f32>> {
        let b = tokens.len();
        if b != caches.len() {
            bail!("decode_batch: {} tokens but {} caches", b, caches.len());
        }
        if b == 0 {
            return Ok(Vec::new());
        }
        let d = self.geom.d_model;
        let mut x = self.gather_embed(tokens)?;
        for layer in 0..self.geom.n_layers {
            let ln = &self.layer_names[layer];
            let (mut q, mut k, v) = self.qkv(ln, &x, b)?;
            let mut ctx = vec![0.0f32; b * d];
            for bi in 0..b {
                let abs = caches[bi].pos();
                self.rope_row(&mut q[bi * d..(bi + 1) * d], abs);
                self.rope_row(&mut k[bi * d..(bi + 1) * d], abs);
                caches[bi].write(layer, abs, &k[bi * d..(bi + 1) * d], &v[bi * d..(bi + 1) * d]);
                self.attend_one(
                    &*caches[bi],
                    layer,
                    abs,
                    &q[bi * d..(bi + 1) * d],
                    &mut ctx[bi * d..(bi + 1) * d],
                );
            }
            self.finish_block(ln, &mut x, &ctx, b)?;
        }
        for cache in caches.iter_mut() {
            cache.advance(1);
        }
        self.head_logits(&x, b)
    }

    // -- forward building blocks ---------------------------------------------

    fn gather_embed(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        let d = self.geom.d_model;
        let ed = self.model.fp_tensor("embed").expect("validated at construction").data();
        let mut x = vec![0.0f32; tokens.len() * d];
        for (ti, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= self.geom.vocab {
                bail!("token id {tok} out of vocab {}", self.geom.vocab);
            }
            x[ti * d..(ti + 1) * d].copy_from_slice(&ed[tok * d..(tok + 1) * d]);
        }
        Ok(x)
    }

    /// Pre-norm + the three attention input projections for `b` rows.
    fn qkv(&self, ln: &LayerNames, x: &[f32], b: usize) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.geom.d_model;
        let g1 = self.model.fp_tensor(&ln.ln1).expect("validated");
        let h = rms_norm_rows(x, g1.data(), b, d);
        let q = self.proj(&ln.q, &h, b)?;
        let k = self.proj(&ln.k, &h, b)?;
        let v = self.proj(&ln.v, &h, b)?;
        Ok((q, k, v))
    }

    /// Attention output projection + residual, then the SwiGLU MLP +
    /// residual, for `b` rows in place on `x`.
    fn finish_block(&self, ln: &LayerNames, x: &mut [f32], ctx: &[f32], b: usize) -> Result<()> {
        let o = self.proj(&ln.o, ctx, b)?;
        for (xv, ov) in x.iter_mut().zip(&o) {
            *xv += ov;
        }
        let d = self.geom.d_model;
        let g2 = self.model.fp_tensor(&ln.ln2).expect("validated");
        let h = rms_norm_rows(x, g2.data(), b, d);
        let gate = self.proj(&ln.gate, &h, b)?;
        let up = self.proj(&ln.up, &h, b)?;
        let mut act = vec![0.0f32; gate.len()];
        for j in 0..gate.len() {
            act[j] = silu(gate[j]) * up[j];
        }
        let down = self.proj(&ln.down, &act, b)?;
        for (xv, dv) in x.iter_mut().zip(&down) {
            *xv += dv;
        }
        Ok(())
    }

    /// One projection over `b` activation rows: fused packed GEMM when the
    /// projection is quantized, dense row-dot fallback otherwise.
    fn proj(&self, prefix: &str, x: &[f32], b: usize) -> Result<Vec<f32>> {
        if let Some(m) = self.model.matrix(prefix) {
            let mut out = vec![0.0f32; b * m.rows];
            if b == 1 {
                m.matvec_t(x, self.threads, &mut out)?;
            } else {
                m.matmul_t_rows(x, b, self.threads, &mut out)?;
            }
            Ok(out)
        } else {
            let w = self
                .model
                .fp_tensor(&format!("{prefix}.w"))
                .ok_or_else(|| anyhow!("no projection '{prefix}'"))?;
            Ok(dense_rows(w, x, b))
        }
    }

    /// Rotate one (d_model,) row in place at absolute position `pos`
    /// (per-head half-split rotary, matching python/compile/model.py).
    fn rope_row(&self, row: &mut [f32], pos: usize) {
        let hd = self.geom.head_dim();
        let half = hd / 2;
        let p = pos as f32;
        for h in 0..self.geom.n_heads {
            let s = &mut row[h * hd..(h + 1) * hd];
            for i in 0..half {
                let (sin, cos) = (p * self.freqs[i]).sin_cos();
                let (x1, x2) = (s[i], s[i + half]);
                s[i] = x1 * cos - x2 * sin;
                s[i + half] = x1 * sin + x2 * cos;
            }
        }
    }

    /// Causal attention of one already-roped query row at absolute
    /// position `abs` over the cache window (which already contains
    /// `abs`). Writes the (d_model,) context row.
    fn attend_one(&self, cache: &KvCache, layer: usize, abs: usize, q: &[f32], ctx: &mut [f32]) {
        let (hh, hd) = (self.geom.n_heads, self.geom.head_dim());
        let inv = 1.0 / (hd as f32).sqrt();
        let n = cache.window_len(abs);
        let start = abs + 1 - n;
        let mut scores = vec![0.0f32; n];
        for h in 0..hh {
            let qh = &q[h * hd..(h + 1) * hd];
            let mut maxs = f32::NEG_INFINITY;
            for (j, sc) in scores.iter_mut().enumerate() {
                let kh = &cache.k_row(layer, start + j)[h * hd..(h + 1) * hd];
                let mut dot = 0.0f32;
                for t in 0..hd {
                    dot += qh[t] * kh[t];
                }
                *sc = dot * inv;
                if *sc > maxs {
                    maxs = *sc;
                }
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - maxs).exp();
                denom += *sc;
            }
            let cxh = &mut ctx[h * hd..(h + 1) * hd];
            cxh.fill(0.0);
            for (j, &w) in scores.iter().enumerate() {
                let p = w / denom;
                let vh = &cache.v_row(layer, start + j)[h * hd..(h + 1) * hd];
                for t in 0..hd {
                    cxh[t] += p * vh[t];
                }
            }
        }
    }

    /// Final RMSNorm + LM head over `b` rows → `(b, vocab)` logits.
    fn head_logits(&self, x: &[f32], b: usize) -> Result<Vec<f32>> {
        let d = self.geom.d_model;
        let gf = self.model.fp_tensor("final_norm.g").expect("validated");
        let xn = rms_norm_rows(&x[..b * d], gf.data(), b, d);
        let head = self.model.fp_tensor(self.head_name).expect("validated");
        Ok(dense_rows(head, &xn, b))
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RMSNorm over `b` rows of width `d`: g · x · rsqrt(mean(x²) + ε).
fn rms_norm_rows(x: &[f32], g: &[f32], b: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b * d];
    for bi in 0..b {
        let xr = &x[bi * d..(bi + 1) * d];
        let mut ss = 0.0f32;
        for &v in xr {
            ss += v * v;
        }
        let inv = 1.0 / (ss / d as f32 + RMS_EPS).sqrt();
        let orow = &mut out[bi * d..(bi + 1) * d];
        for j in 0..d {
            orow[j] = g[j] * xr[j] * inv;
        }
    }
    out
}

/// Dense projection fallback and LM head: y (b, out) = X · Wᵀ with
/// W row-major (out, in), accumulated row by row in a fixed order
/// (deterministic, batch-row independent).
fn dense_rows(w: &Tensor, x: &[f32], b: usize) -> Vec<f32> {
    let (o, i) = w.dims2().expect("dense projection is 2-D");
    let wd = w.data();
    let mut y = vec![0.0f32; b * o];
    for bi in 0..b {
        let xr = &x[bi * i..(bi + 1) * i];
        let yr = &mut y[bi * o..(bi + 1) * o];
        for (r, yv) in yr.iter_mut().enumerate() {
            let wr = &wd[r * i..(r + 1) * i];
            let mut acc = 0.0f32;
            for j in 0..i {
                acc += xr[j] * wr[j];
            }
            *yv = acc;
        }
    }
    y
}

/// Parity baseline: full causal forward over a *dense* fp checkpoint
/// (the dequantized view of the packed model) using the seed's
/// single-threaded `matmul_naive` for every projection. Returns the
/// `(T, vocab)` logits tensor. No KV cache, no packed codes — this is
/// the "unpack → dequantize → naive matmul" path the fused engine is
/// verified against (decode parity ≤ 1e-4).
pub fn reference_forward(fp: &Checkpoint, geom: &ModelGeom, tokens: &[u32]) -> Result<Tensor> {
    let t_len = tokens.len();
    if t_len == 0 {
        bail!("reference_forward needs at least one token");
    }
    let d = geom.d_model;
    let (hh, hd) = (geom.n_heads, geom.head_dim());
    let half = hd / 2;
    let embed = fp.req("embed")?;
    let mut x = vec![0.0f32; t_len * d];
    for (ti, &tok) in tokens.iter().enumerate() {
        x[ti * d..(ti + 1) * d]
            .copy_from_slice(&embed.data()[tok as usize * d..(tok as usize + 1) * d]);
    }
    let freqs: Vec<f32> = (0..half)
        .map(|i| 10000.0f32.powf(-(i as f32) / half as f32))
        .collect();
    let rope = |row: &mut [f32], pos: usize| {
        let p = pos as f32;
        for h in 0..hh {
            let s = &mut row[h * hd..(h + 1) * hd];
            for i in 0..half {
                let (sin, cos) = (p * freqs[i]).sin_cos();
                let (x1, x2) = (s[i], s[i + half]);
                s[i] = x1 * cos - x2 * sin;
                s[i + half] = x1 * sin + x2 * cos;
            }
        }
    };
    let proj = |name: String, h: &[f32]| -> Result<Vec<f32>> {
        let w = fp.req(&name)?;
        let (_, cin) = w.dims2()?;
        let ht = Tensor::new(&[h.len() / cin, cin], h.to_vec());
        Ok(ht.matmul_naive(&w.t())?.into_data())
    };
    let inv = 1.0 / (hd as f32).sqrt();
    for layer in 0..geom.n_layers {
        let lp = format!("layers.{layer}");
        let h = rms_norm_rows(&x, fp.req(&format!("{lp}.ln1.g"))?.data(), t_len, d);
        let mut q = proj(format!("{lp}.attn.q.w"), &h)?;
        let mut k = proj(format!("{lp}.attn.k.w"), &h)?;
        let v = proj(format!("{lp}.attn.v.w"), &h)?;
        for ti in 0..t_len {
            rope(&mut q[ti * d..(ti + 1) * d], ti);
            rope(&mut k[ti * d..(ti + 1) * d], ti);
        }
        let mut ctx = vec![0.0f32; t_len * d];
        for ti in 0..t_len {
            for hi in 0..hh {
                let qh = &q[ti * d + hi * hd..ti * d + (hi + 1) * hd];
                let mut scores = vec![0.0f32; ti + 1];
                let mut maxs = f32::NEG_INFINITY;
                for (j, sc) in scores.iter_mut().enumerate() {
                    let kh = &k[j * d + hi * hd..j * d + (hi + 1) * hd];
                    let mut dot = 0.0f32;
                    for t in 0..hd {
                        dot += qh[t] * kh[t];
                    }
                    *sc = dot * inv;
                    if *sc > maxs {
                        maxs = *sc;
                    }
                }
                let mut denom = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxs).exp();
                    denom += *sc;
                }
                let cxh = &mut ctx[ti * d + hi * hd..ti * d + (hi + 1) * hd];
                for (j, &w) in scores.iter().enumerate() {
                    let p = w / denom;
                    let vh = &v[j * d + hi * hd..j * d + (hi + 1) * hd];
                    for t in 0..hd {
                        cxh[t] += p * vh[t];
                    }
                }
            }
        }
        let o = proj(format!("{lp}.attn.o.w"), &ctx)?;
        for (xv, ov) in x.iter_mut().zip(&o) {
            *xv += ov;
        }
        let h2 = rms_norm_rows(&x, fp.req(&format!("{lp}.ln2.g"))?.data(), t_len, d);
        let gate = proj(format!("{lp}.mlp.gate.w"), &h2)?;
        let up = proj(format!("{lp}.mlp.up.w"), &h2)?;
        let mut act = vec![0.0f32; gate.len()];
        for j in 0..gate.len() {
            act[j] = silu(gate[j]) * up[j];
        }
        let down = proj(format!("{lp}.mlp.down.w"), &act)?;
        for (xv, dv) in x.iter_mut().zip(&down) {
            *xv += dv;
        }
    }
    let xn = rms_norm_rows(&x, fp.req("final_norm.g")?.data(), t_len, d);
    let head = match fp.get("lm_head") {
        Some(h) => h,
        None => embed,
    };
    Tensor::new(&[t_len, d], xn).matmul_naive(&head.t())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins_ties() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
        // NaN never compares greater, so it neither wins nor crashes:
        // a leading NaN stays "best", an interior NaN is skipped.
        assert_eq!(argmax(&[f32::NAN, 2.0, 2.0]), 0);
        assert_eq!(argmax(&[1.0, f32::NAN, 3.0]), 2);
    }

    #[test]
    fn topk_sampling_is_seeded_and_respects_k() {
        let logits = vec![0.1, 5.0, 4.0, -2.0, 3.0];
        // k = 1 degenerates to greedy regardless of the rng.
        let mut rng = Pcg32::new(1);
        for _ in 0..8 {
            assert_eq!(sample(&logits, Sampling::TopK { k: 1, temperature: 1.0 }, &mut rng), 1);
        }
        // Same seed → same draws; all draws land in the top-3 set.
        let draws = |seed: u64| -> Vec<u32> {
            let mut rng = Pcg32::new(seed);
            (0..32)
                .map(|_| sample(&logits, Sampling::TopK { k: 3, temperature: 1.0 }, &mut rng))
                .collect()
        };
        let a = draws(7);
        assert_eq!(a, draws(7));
        assert!(a.iter().all(|t| [1u32, 2, 4].contains(t)), "{a:?}");
        assert_ne!(a, draws(8));
    }

    #[test]
    fn geometry_validation() {
        let ok = ModelGeom { vocab: 16, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 12 };
        assert!(ok.validated().is_ok());
        assert_eq!(ok.head_dim(), 4);
        let odd_head = ModelGeom { n_heads: 4, ..ok }; // head_dim 2 ok
        assert!(odd_head.validated().is_ok());
        let bad_div = ModelGeom { n_heads: 3, ..ok };
        assert!(bad_div.validated().is_err());
        let odd = ModelGeom { d_model: 6, n_heads: 2, ..ok }; // head_dim 3
        assert!(odd.validated().is_err());
        let zero = ModelGeom { n_layers: 0, ..ok };
        assert!(zero.validated().is_err());
    }
}
