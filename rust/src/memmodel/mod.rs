//! Analytic DRAM model — reproduces Table 1, Table 4 and Figure 2a.
//!
//! The paper's memory numbers decompose into: model weights (fp16 or
//! b-bit packed + fp scales), optimizer state (AdamW m+v over trainable
//! params only), gradients over trainable params, and (for full FT)
//! fp32 master weights. This module computes those for *any* model
//! geometry, so the benches can print both the paper's real LLaMA-65B
//! dims and our scaled family from the same code.

use crate::util::decimal_gb;

/// Model geometry: one entry per weight matrix (rows = out, cols = in).
#[derive(Clone, Debug)]
pub struct Geometry {
    pub name: String,
    /// (rows, cols, quantizable) for every parameter tensor.
    pub tensors: Vec<(usize, usize, bool)>,
}

impl Geometry {
    /// LLaMA-style decoder geometry from hyperparameters.
    pub fn llama(name: &str, vocab: usize, d: usize, layers: usize, d_ff: usize) -> Self {
        let mut tensors = vec![(vocab, d, false)]; // embedding
        for _ in 0..layers {
            tensors.push((1, d, false)); // ln1
            for _ in 0..4 {
                tensors.push((d, d, true)); // q,k,v,o
            }
            tensors.push((1, d, false)); // ln2
            tensors.push((d_ff, d, true)); // gate
            tensors.push((d_ff, d, true)); // up
            tensors.push((d, d_ff, true)); // down
        }
        tensors.push((1, d, false)); // final norm
        tensors.push((vocab, d, false)); // lm head
        Geometry { name: name.to_string(), tensors }
    }

    /// The real LLaMA-65B geometry (for paper-dims sanity rows).
    pub fn llama_65b() -> Self {
        Geometry::llama("LLaMA-65B", 32000, 8192, 80, 22016)
    }

    pub fn n_params(&self) -> u64 {
        self.tensors.iter().map(|&(n, m, _)| (n * m) as u64).sum()
    }

    pub fn n_quantizable(&self) -> u64 {
        self.tensors.iter().filter(|t| t.2).map(|&(n, m, _)| (n * m) as u64).sum()
    }
}

/// Fine-tuning method for memory accounting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    FullFt,
    /// LoRA with (#target matrices per layer × layers, rank) already folded
    /// into `trainable_params`.
    Peft { trainable_params: u64 },
    PeftPtq { trainable_params: u64, bits: u8 },
    PtqPeft { trainable_params: u64, bits: u8 },
    Peqa { bits: u8, group: Option<usize> },
}

#[derive(Clone, Debug)]
pub struct MemoryReport {
    pub method: &'static str,
    pub finetune_bytes: u64,
    pub deploy_bytes: u64,
    pub trainable_params: u64,
    pub fast_inference: bool,
    pub fast_switching: bool,
}

const FP16: u64 = 2;
const FP32: u64 = 4;

fn packed_weight_bytes(geom: &Geometry, bits: u8, group: Option<usize>) -> u64 {
    // Quantizable tensors: packed codes + fp16 scale/zero per (row, group);
    // everything else stays fp16.
    let mut total = 0u64;
    for &(n, m, quant) in &geom.tensors {
        let params = (n * m) as u64;
        if quant {
            let g = group.unwrap_or(m);
            let groups = (n as u64) * (m / g) as u64;
            total += (params * bits as u64).div_ceil(8) + 2 * groups * FP16;
        } else {
            total += params * FP16;
        }
    }
    total
}

/// PEQA trainable-parameter count: one scale per (channel, group).
pub fn peqa_trainable(geom: &Geometry, group: Option<usize>) -> u64 {
    geom.tensors
        .iter()
        .filter(|t| t.2)
        .map(|&(n, m, _)| (n as u64) * (m / group.unwrap_or(m)) as u64)
        .sum()
}

/// LoRA trainable-parameter count for `targets_per_layer` adapted (d×d)
/// matrices across `layers` layers at `rank`.
pub fn lora_trainable(d: usize, layers: usize, targets_per_layer: usize, rank: usize) -> u64 {
    (2 * d * rank * targets_per_layer * layers) as u64
}

/// DRAM for fine-tuning and deployment (Table 1 semantics: weights +
/// gradients + AdamW state; activations excluded as in the paper).
pub fn report(geom: &Geometry, method: Method) -> MemoryReport {
    let fp16_model = geom.n_params() * FP16;
    match method {
        Method::FullFt => MemoryReport {
            method: "Full Fine-Tuning",
            // Pure-fp16 AdamW: weights + grads + m + v, all fp16 (8 B/param
            // ≈ 521 GB at 65B; the paper measured 457 GB with DeepSpeed —
            // same order, and the 14× gap to PEQA is preserved).
            finetune_bytes: fp16_model * 4,
            deploy_bytes: fp16_model,
            trainable_params: geom.n_params(),
            fast_inference: false,
            fast_switching: false,
        },
        Method::Peft { trainable_params: t } => MemoryReport {
            method: "PEFT",
            finetune_bytes: fp16_model + t * (FP16 + 2 * FP32),
            deploy_bytes: fp16_model,
            trainable_params: t,
            fast_inference: false,
            fast_switching: true,
        },
        Method::PeftPtq { trainable_params: t, bits } => MemoryReport {
            method: "PEFT+PTQ",
            finetune_bytes: fp16_model + t * (FP16 + 2 * FP32),
            deploy_bytes: packed_weight_bytes(geom, bits, None),
            trainable_params: t,
            fast_inference: true,
            fast_switching: false, // PTQ after PEFT is non-reversible
        },
        Method::PtqPeft { trainable_params: t, bits } => MemoryReport {
            method: "PTQ+PEFT",
            finetune_bytes: packed_weight_bytes(geom, bits, None) + t * (FP16 + 2 * FP32),
            deploy_bytes: packed_weight_bytes(geom, bits, None),
            trainable_params: t,
            fast_inference: false, // fp adapters stay outside the int kernel
            fast_switching: true,
        },
        Method::Peqa { bits, group } => {
            let t = peqa_trainable(geom, group);
            let packed = packed_weight_bytes(geom, bits, group);
            MemoryReport {
                method: "PEQA (Ours)",
                finetune_bytes: packed + t * (FP16 + 2 * FP32),
                deploy_bytes: packed,
                trainable_params: t,
                fast_inference: true,
                fast_switching: true,
            }
        }
    }
}

pub fn fmt_row(r: &MemoryReport) -> String {
    format!(
        "{:18} {:>10} {:>10}   {:9} {:9}   {:>12}",
        r.method,
        decimal_gb(r.finetune_bytes),
        decimal_gb(r.deploy_bytes),
        if r.fast_inference { "Fast" } else { "Slow" },
        if r.fast_switching { "Fast" } else { "Slow" },
        r.trainable_params,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama65b_matches_paper_scale() {
        let g = Geometry::llama_65b();
        // ~65B params (the public model is 65.2B).
        let p = g.n_params() as f64 / 1e9;
        assert!((60.0..70.0).contains(&p), "{p}B");
        // fp16 model ≈ 131 GB (Table 1 deploy row for full FT / PEFT).
        let full = report(&g, Method::FullFt);
        let gb = full.deploy_bytes as f64 / 1e9;
        assert!((120.0..140.0).contains(&gb), "{gb} GB");
        // 4-bit PEQA deploy ≈ 33 GB (Table 1 last row).
        let peqa = report(&g, Method::Peqa { bits: 4, group: None });
        let gb = peqa.deploy_bytes as f64 / 1e9;
        assert!((30.0..36.0).contains(&gb), "{gb} GB");
        // Full fine-tuning ≈ 457 GB DRAM (Table 1 first row; our analytic
        // pure-fp16-AdamW model gives ~521 GB — same order).
        let gb = full.finetune_bytes as f64 / 1e9;
        assert!((420.0..560.0).contains(&gb), "{gb} GB");
    }

    #[test]
    fn peqa_trainable_close_to_paper() {
        // Paper Table 4: LLaMA-65B has 6.8M PEQA-trainable params
        // (per-channel) vs 10.49M for LoRA QV4 — ratio ≈ 1.54.
        let g = Geometry::llama_65b();
        let peqa = peqa_trainable(&g, None) as f64 / 1e6;
        assert!((6.0..7.5).contains(&peqa), "{peqa}M");
        let lora = lora_trainable(8192, 80, 2, 4) as f64 / 1e6;
        assert!((10.0..11.0).contains(&lora), "{lora}M");
        assert!((lora / peqa - 1.54).abs() < 0.15);
    }

    #[test]
    fn table1_orderings() {
        let g = Geometry::llama_65b();
        let lora_t = lora_trainable(8192, 80, 2, 4);
        let full = report(&g, Method::FullFt);
        let peft = report(&g, Method::Peft { trainable_params: lora_t });
        let peft_ptq = report(&g, Method::PeftPtq { trainable_params: lora_t, bits: 4 });
        let ptq_peft = report(&g, Method::PtqPeft { trainable_params: lora_t, bits: 4 });
        let peqa = report(&g, Method::Peqa { bits: 4, group: None });
        // Fine-tuning DRAM: full >> peft == peft_ptq > ptq_peft ≈ peqa.
        assert!(full.finetune_bytes > 3 * peft.finetune_bytes);
        assert_eq!(peft.finetune_bytes, peft_ptq.finetune_bytes);
        assert!(ptq_peft.finetune_bytes < peft.finetune_bytes / 3);
        assert!(peqa.finetune_bytes < peft.finetune_bytes / 3);
        // Only PEQA is fast on both axes (the Table 1 punchline).
        assert!(peqa.fast_inference && peqa.fast_switching);
        assert!(!peft_ptq.fast_switching && !ptq_peft.fast_inference);
    }

    #[test]
    fn three_bit_smaller_than_four_bit() {
        let g = Geometry::llama_65b();
        let b4 = report(&g, Method::Peqa { bits: 4, group: None }).deploy_bytes;
        let b3 = report(&g, Method::Peqa { bits: 3, group: None }).deploy_bytes;
        assert!(b3 < b4);
        // Paper Table 4: 33.45 GB vs 25.35 GB for 65B — ratio ~0.76.
        let ratio = b3 as f64 / b4 as f64;
        assert!((0.72..0.80).contains(&ratio), "{ratio}");
    }

    #[test]
    fn grouping_adds_scale_params() {
        let g = Geometry::llama_65b();
        assert!(peqa_trainable(&g, Some(256)) > peqa_trainable(&g, None));
        assert!(peqa_trainable(&g, Some(64)) > peqa_trainable(&g, Some(256)));
    }
}
