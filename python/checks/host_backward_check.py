#!/usr/bin/env python3
"""Numerics cross-check for the host PEQA backward (rust/src/train/host.rs).

Standalone (numpy only — no jax): run `python3 python/checks/host_backward_check.py`.

Mirrors, in f64 numpy, EXACTLY the formulas the Rust implements:
  * fused projection y = X @ (s*(c - z)).T with per-(row, group) s/z
  * grad_input        dX = dY @ W_hat
  * grad_scales_zeros ds[r,g], dz[r,g] reductions
  * rmsnorm fwd/bwd, rope fwd/bwd, causal attention fwd/bwd,
    SwiGLU fwd/bwd, masked-CE loss + dlogits
then finite-difference-verifies every gradient in f64 (so any algebra
error in the ported formulas shows as O(1) relative error), and finally
simulates the e2e test's scale-only Adam training run to confirm the
loss-decrease margins asserted in tests/train_host.rs.
"""
import numpy as np

rng = np.random.default_rng(0)

# ---------------------------------------------------------------- quant
def quantize(W, bits, group):
    rows, cols = W.shape
    g = group or cols
    ng = cols // g
    Wg = W.reshape(rows, ng, g)
    mn, mx = Wg.min(-1), Wg.max(-1)
    qmax = (1 << bits) - 1
    s = np.maximum((mx - mn) / qmax, 1e-8)
    z = -mn / s
    c = np.clip(np.round(Wg / s[..., None] + z[..., None]), 0, qmax)
    return c, s, z  # c: (rows, ng, g)

def dequant(c, s, z):
    return (s[..., None] * (c - z[..., None])).reshape(c.shape[0], -1)

def proj(x, c, s, z):
    return x @ dequant(c, s, z).T

def grad_input(dy, c, s, z):
    return dy @ dequant(c, s, z)

def grad_sz(x, dy, c, s, z):
    rows, ng, g = c.shape
    xg = x.reshape(x.shape[0], ng, g)
    sx = xg.sum(-1)                                  # (b, ng)
    dot = np.einsum('big,rig->bri', xg, c)           # (b, rows, ng)
    ds = np.einsum('br,bri->ri', dy, dot) - z * np.einsum('br,bi->ri', dy, sx)
    dz = -s * np.einsum('br,bi->ri', dy, sx)
    return ds, dz

# kernel-level fd check (linear loss)
for bits, group in [(2, None), (3, 16), (4, 128)]:
    cols = 256
    W = rng.normal(0, 0.4, (12, cols))
    c, s, z = quantize(W, bits, group)
    x = rng.normal(0, 1, (5, cols))
    wts = rng.normal(0, 1, (5, 12))
    loss = lambda s_, z_: float((proj(x, c, s_, z_) * wts).sum())
    ds, dz = grad_sz(x, wts, c, s, z)
    h = 1e-5
    for (r, g_) in [(0, 0), (5, s.shape[1]//2), (11, s.shape[1]-1)]:
        for which, grad in [("s", ds), ("z", dz)]:
            t = s if which == "s" else z
            t2 = t.copy(); t2[r, g_] += h
            lp = loss(t2 if which == "s" else s, t2 if which == "z" else z)
            t2[r, g_] -= 2*h
            lm = loss(t2 if which == "s" else s, t2 if which == "z" else z)
            fd = (lp - lm) / (2*h)
            assert abs(fd - grad[r, g_]) <= 1e-6 * max(1, abs(fd)), (bits, group, which, r, g_, fd, grad[r, g_])
    # grad_input vs dense
    dy = rng.normal(0, 1, (5, 12))
    assert np.allclose(grad_input(dy, c, s, z), dy @ dequant(c, s, z))
print("kernel-level grads: OK")

# ------------------------------------------------------------- model fwd/bwd
RMS_EPS = 1e-6

def rms(x, g):
    inv = 1.0 / np.sqrt((x*x).mean(-1, keepdims=True) + RMS_EPS)
    return g * x * inv, inv[..., 0]

def rms_bwd(dy, x, g, inv):
    d = x.shape[-1]
    ssum = (dy * g * x).sum(-1, keepdims=True)
    return inv[..., None] * g * dy - x * (inv[..., None]**3) * ssum / d

def rope_mat(T, hh, hd):
    half = hd // 2
    freqs = 10000.0 ** (-np.arange(half) / half)
    ang = np.arange(T)[:, None] * freqs[None, :]   # (T, half)
    return np.sin(ang), np.cos(ang)

def rope(x, sin, cos, hh, hd, sign=1.0):
    # x: (B, T, d); per head half-split rotation; sign=-1 is backward.
    B, T, d = x.shape
    half = hd // 2
    xh = x.reshape(B, T, hh, hd).copy()
    x1 = xh[..., :half].copy(); x2 = xh[..., half:].copy()
    s = sign * sin[None, :, None, :]; c_ = cos[None, :, None, :]
    xh[..., :half] = x1 * c_ - x2 * s
    xh[..., half:] = x1 * s + x2 * c_
    return xh.reshape(B, T, d)

def silu(x): return x / (1 + np.exp(-x))
def silu_grad(x):
    s = 1/(1+np.exp(-x)); return s * (1 + x * (1 - s))

class Model:
    def __init__(self, vocab, d, L, hh, dff, bits=4, group=8):
        self.vocab, self.d, self.L, self.hh, self.dff = vocab, d, L, hh, dff
        self.hd = d // hh
        self.embed = rng.normal(0, 0.06, (vocab, d))
        self.head = rng.normal(0, 0.06, (vocab, d))
        self.gf = np.ones(d)
        self.layers = []
        for _ in range(L):
            lay = {"g1": np.ones(d), "g2": np.ones(d)}
            for name, shape in [("q", (d, d)), ("k", (d, d)), ("v", (d, d)), ("o", (d, d)),
                                ("gate", (dff, d)), ("up", (dff, d)), ("down", (d, dff))]:
                W = rng.normal(0, 0.08, shape)
                lay[name] = quantize(W, bits, group)
            self.layers.append(lay)

    def params(self):
        out = []
        for li, lay in enumerate(self.layers):
            for n in ["q", "k", "v", "o", "gate", "up", "down"]:
                out.append((li, n))
        return out

    def forward(self, tokens, tape=None):
        B, T = tokens.shape
        d, hh, hd = self.d, self.hh, self.hd
        sin, cos = rope_mat(T, hh, hd)
        x = self.embed[tokens]
        inv_sqrt = 1/np.sqrt(hd)
        tp_layers = []
        for lay in self.layers:
            t = {"x_in": x.copy()}
            h1, inv1 = rms(x, lay["g1"])
            t["h1"], t["inv1"] = h1, inv1
            q = proj(h1.reshape(-1, d), *lay["q"]).reshape(B, T, d)
            k = proj(h1.reshape(-1, d), *lay["k"]).reshape(B, T, d)
            v = proj(h1.reshape(-1, d), *lay["v"]).reshape(B, T, d)
            q = rope(q, sin, cos, hh, hd); k = rope(k, sin, cos, hh, hd)
            t["q"], t["k"], t["v"] = q, k, v
            # causal attention per head
            qh = q.reshape(B, T, hh, hd).transpose(0, 2, 1, 3)
            kh = k.reshape(B, T, hh, hd).transpose(0, 2, 1, 3)
            vh = v.reshape(B, T, hh, hd).transpose(0, 2, 1, 3)
            sc = np.einsum('bhtd,bhjd->bhtj', qh, kh) * inv_sqrt
            mask = np.tril(np.ones((T, T), bool))
            sc = np.where(mask, sc, -np.inf)
            sc -= sc.max(-1, keepdims=True)
            P = np.exp(sc); P /= P.sum(-1, keepdims=True)
            t["P"] = P
            ctx = np.einsum('bhtj,bhjd->bhtd', P, vh).transpose(0, 2, 1, 3).reshape(B, T, d)
            t["ctx"] = ctx
            o = proj(ctx.reshape(-1, d), *lay["o"]).reshape(B, T, d)
            x = x + o
            t["x_mid"] = x.copy()
            h2, inv2 = rms(x, lay["g2"])
            t["h2"], t["inv2"] = h2, inv2
            gate = proj(h2.reshape(-1, d), *lay["gate"]).reshape(B, T, self.dff)
            up = proj(h2.reshape(-1, d), *lay["up"]).reshape(B, T, self.dff)
            act = silu(gate) * up
            t["gate"], t["up"], t["act"] = gate, up, act
            dn = proj(act.reshape(-1, self.dff), *lay["down"]).reshape(B, T, d)
            x = x + dn
            tp_layers.append(t)
        x_final = x
        xn, invf = rms(x_final, self.gf)
        logits = xn @ self.head.T
        if tape is not None:
            tape.update(layers=tp_layers, x_final=x_final, invf=invf, logits=logits)
        return logits

    def loss(self, tokens, mask):
        logits = self.forward(tokens)
        return self._loss_from(logits, tokens, mask)

    def _loss_from(self, logits, tokens, mask):
        B, T = tokens.shape
        lg = logits[:, :-1]
        tg = tokens[:, 1:]
        mx = lg.max(-1, keepdims=True)
        lse = np.log(np.exp(lg - mx).sum(-1)) + mx[..., 0]
        nll = lse - np.take_along_axis(lg, tg[..., None], -1)[..., 0]
        return (nll * mask).sum() / mask.sum()

    def backward(self, tokens, mask):
        B, T = tokens.shape
        d, hh, hd, dff = self.d, self.hh, self.hd, self.dff
        sin, cos = rope_mat(T, hh, hd)
        tape = {}
        logits = self.forward(tokens, tape)
        denom = mask.sum()
        # dlogits
        lg = logits[:, :-1]
        mx = lg.max(-1, keepdims=True)
        e = np.exp(lg - mx); sm = e / e.sum(-1, keepdims=True)
        dl = sm * (mask[..., None] / denom)
        np.put_along_axis(dl, tokens[:, 1:][..., None],
                          np.take_along_axis(dl, tokens[:, 1:][..., None], -1) - mask[..., None]/denom, -1)
        dlogits = np.zeros_like(logits)
        dlogits[:, :-1] = dl
        grads = {}
        dxn = dlogits @ self.head
        dx = rms_bwd(dxn, tape["x_final"], self.gf, tape["invf"])
        inv_sqrt = 1/np.sqrt(hd)
        for li in reversed(range(self.L)):
            lay, t = self.layers[li], tape["layers"][li]
            def pb(name, x_in, dy):
                c, s, z = lay[name]
                grads[(li, name)] = grad_sz(x_in.reshape(-1, x_in.shape[-1]), dy.reshape(-1, dy.shape[-1]), c, s, z)
                return grad_input(dy.reshape(-1, dy.shape[-1]), c, s, z).reshape(x_in.shape)
            da = pb("down", t["act"], dx)
            dgate = da * t["up"] * silu_grad(t["gate"])
            dup = da * silu(t["gate"])
            dh2 = pb("gate", t["h2"], dgate) + pb("up", t["h2"], dup)
            dx2 = rms_bwd(dh2, t["x_mid"], lay["g2"], t["inv2"]) + dx
            dctx = pb("o", t["ctx"], dx2)
            # attention backward
            P = t["P"]
            vh = t["v"].reshape(B, T, hh, hd).transpose(0, 2, 1, 3)
            qh = t["q"].reshape(B, T, hh, hd).transpose(0, 2, 1, 3)
            kh = t["k"].reshape(B, T, hh, hd).transpose(0, 2, 1, 3)
            dctx_h = dctx.reshape(B, T, hh, hd).transpose(0, 2, 1, 3)
            dP = np.einsum('bhtd,bhjd->bhtj', dctx_h, vh)
            dV = np.einsum('bhtj,bhtd->bhjd', P, dctx_h)
            row = (dP * P).sum(-1, keepdims=True)
            dS = P * (dP - row) * inv_sqrt
            dQ = np.einsum('bhtj,bhjd->bhtd', dS, kh)
            dK = np.einsum('bhtj,bhtd->bhjd', dS, qh)
            dq = dQ.transpose(0, 2, 1, 3).reshape(B, T, d)
            dk = dK.transpose(0, 2, 1, 3).reshape(B, T, d)
            dv = dV.transpose(0, 2, 1, 3).reshape(B, T, d)
            dq = rope(dq, sin, cos, hh, hd, sign=-1.0)
            dk = rope(dk, sin, cos, hh, hd, sign=-1.0)
            dh1 = pb("q", t["h1"], dq) + pb("k", t["h1"], dk) + pb("v", t["h1"], dv)
            dx = rms_bwd(dh1, t["x_in"], lay["g1"], t["inv1"]) + dx2
        return grads

# fd check of the full model gradient
m = Model(64, 16, 2, 2, 32)
tokens = rng.integers(0, 64, (3, 10))
mask = np.ones((3, 9))
grads = m.backward(tokens, mask)
h = 1e-6
worst = 0.0
for (li, name) in [(0, "q"), (0, "down"), (1, "o"), (1, "gate"), (0, "v"), (1, "up"), (0, "k")]:
    c, s, z = m.layers[li][name]
    ds, dz = grads[(li, name)]
    for which, t, g in [("s", s, ds), ("z", z, dz)]:
        idx = np.unravel_index(np.argmax(np.abs(g)), g.shape)
        orig = t[idx]
        t[idx] = orig + h; lp = m.loss(tokens, mask)
        t[idx] = orig - h; lm = m.loss(tokens, mask)
        t[idx] = orig
        fd = (lp - lm) / (2*h)
        rel = abs(fd - g[idx]) / max(abs(fd), 1e-10)
        worst = max(worst, rel)
        assert rel < 1e-4, (li, name, which, idx, fd, g[idx], rel)
print(f"full-model grads: OK (worst rel {worst:.2e})")

# --------------------------------------------- e2e training simulation
# Mirror tests/train_host.rs::finetune_then_serve_closes_the_loop scale:
# vocab 512, d 32, L 2, H 2, dff 64, motif-16 data, B3 T24, 30 steps,
# Adam lr 5e-3 warmup 2 linear decay, scales only.
m = Model(512, 32, 2, 2, 64, bits=4, group=16)
motif = (np.arange(16) * 37 + 11) % 500
stream = np.tile(motif, 150)
B, T, steps, lr0 = 3, 24, 30, 5e-3
adam = {}
losses = []
srng = np.random.default_rng(7)
for step in range(1, steps+1):
    starts = srng.integers(0, len(stream) - T, B)
    tokens = np.stack([stream[s0:s0+T] for s0 in starts])
    mask = np.ones((B, T-1))
    grads = m.backward(tokens, mask)
    losses.append(m.loss(tokens, mask))
    # lr schedule: warmup 2 then linear decay to 0 (lr_final_frac 0)
    warm = 2
    if step <= warm:
        lr = lr0 * step / warm
    else:
        frac = max(0.0, min(1.0, (steps - step) / max(1.0, steps - warm)))
        lr = lr0 * frac
    for key, (ds, dz) in grads.items():
        c, s, z = m.layers[key[0]][key[1]]
        st = adam.setdefault(key, [np.zeros_like(s), np.zeros_like(s)])
        st[0] = 0.9*st[0] + 0.1*ds
        st[1] = 0.999*st[1] + 0.001*ds*ds
        mh = st[0]/(1-0.9**step); vh = st[1]/(1-0.999**step)
        s -= lr * mh / (np.sqrt(vh) + 1e-8)
first, tail = losses[0], np.mean(losses[-5:])
print(f"train sim: loss {first:.4f} -> last5 {tail:.4f} (drop {first-tail:.4f})")
assert tail < first - 0.05, "e2e loss-drop margin would fail"
print("e2e training margin: OK")
