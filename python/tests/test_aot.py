"""AOT pipeline tests: manifest sanity, HLO text emission, meta integrity."""

import json

import jax.numpy as jnp
import pytest

from compile import aot, configs
from compile.configs import SIZES
from compile.model import MethodConfig


def test_manifest_names_unique_and_complete():
    arts = configs.manifest()
    names = [a.name for a in arts]
    assert len(names) == len(set(names))
    # Every experiment in DESIGN.md needs these artifact classes:
    need = [
        "n1_train_qat_b3", "n1_train_qat_b4",          # Table 2
        "n6_train_peqa_b3_gc", "n6_train_lora_qv4",    # Table 3
        "n3_train_peqa_b4_g64", "n4_train_peqa_b3_g16",  # Table 5
        "n3_train_lora_qkvo16", "n3_logits_b8",        # Tables 6/7
        "o6_train_peqa_b4_gc",                         # Table 10
        "n1_train_alpha_b3", "n2_train_alpha_b4",      # Table 15
        "n3_train_peqa_zp_b4_gc", "n4_train_peqa_szp_b4_gc",  # Table 17
        "n3_logits_q_b4_gc_b1",                        # serving path
        "n3_hess",                                     # OPTQ calibration
    ]
    for n in need:
        assert n in names, n


@pytest.mark.parametrize("size", ["n1", "o1"])
def test_train_artifact_builds_and_meta_consistent(size):
    art = next(
        a for a in configs.manifest() if a.name == f"{size}_train_peqa_b4_gc"
    )
    fn, args, meta = aot.build(art)
    assert len(args) == len(meta["inputs"])
    for spec, io in zip(args, meta["inputs"]):
        assert list(spec.shape) == io["shape"]
    # trainable params are exactly the scales for PEQA
    names = [p["name"] for p in meta["params_trainable"]]
    assert names and all(n.endswith(".s") for n in names)
    # outputs: loss + trainable + m + v
    assert len(meta["outputs"]) == 1 + 3 * len(names)


def test_hlo_text_is_parseable_hlo():
    art = next(a for a in configs.manifest() if a.name == "kernel_rtn_256")
    fn, args, meta = aot.build(art)
    text = aot.to_hlo_text(fn, args)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # int ids must be small enough for xla_extension 0.5.1 (text format
    # reassigns ids — just make sure we really emitted text, not proto).
    assert "\x00" not in text


def test_eval_artifact_runs_with_example_inputs():
    """Executing the built eval fn on zeros gives a finite scalar pair."""
    art = next(a for a in configs.manifest() if a.name == "n1_eval")
    fn, args, meta = aot.build(art)
    vals = [jnp.zeros(s.shape, s.dtype) for s in args]
    # ones for the norm gains so the forward is numerically sane
    for i, io in enumerate(meta["inputs"]):
        if io["name"].endswith(".g"):
            vals[i] = jnp.ones(vals[i].shape)
    s, c = fn(*vals)
    assert s.shape == () and c.shape == ()
    assert bool(jnp.isfinite(s))


def test_logits_q_uses_method_layout():
    art = next(
        a for a in configs.manifest() if a.name == "n3_logits_q_b4_gc_b1"
    )
    fn, args, meta = aot.build(art)
    names = [p["name"] for p in meta["params"]]
    assert any(n.endswith(".wq") for n in names)
    assert any(n.endswith(".s") for n in names)
    cfg = SIZES["n3"]
    assert meta["outputs"][0]["shape"] == [1, cfg.seq_len, cfg.vocab]


def test_display_names_cover_all_sizes():
    for s in SIZES:
        assert s in configs.DISPLAY


def test_paper_scale_param_counts_monotone():
    counts = [SIZES[f"n{i}"].n_params() for i in range(1, 7)]
    assert counts == sorted(counts)
    assert counts[-1] / counts[0] > 15  # spans a wide range (Fig. 2b)
