"""Pallas kernels vs pure-jnp oracles — the CORE L1 correctness signal.

Hypothesis sweeps shapes, bit-widths, group sizes and dtypes; every kernel
must match kernels/ref.py within fp tolerance under arbitrary blockings.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import peqa_grad, qmatmul, qmatmul_t, quantize_rtn
from compile.kernels import ref
from compile.kernels.util import pick_block

# Dims are built as (#groups × group-size) so every (m, group) pair is valid.
dims_n = st.sampled_from([8, 16, 24, 64, 96, 128])
group_sz = st.sampled_from([4, 8, 16, 32])
ngroups = st.integers(min_value=1, max_value=6)
bits_st = st.sampled_from([2, 3, 4, 8])
batch_st = st.sampled_from([1, 2, 8, 24])
blocks = st.sampled_from([8, 32, 128])


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=15, deadline=None)
@given(n=dims_n, g=group_sz, G=ngroups, bits=bits_st, seed=st.integers(0, 2**31))
def test_quantize_rtn_matches_ref(n, g, G, bits, seed):
    rng = np.random.default_rng(seed)
    w = _rand(rng, n, g * G)
    wq, s, z = quantize_rtn(w, bits, g, row_block=16)
    wq_r, s_r, z_r = ref.quantize_rtn_ref(w, bits, g)
    # Codes may differ by 1 on round-to-nearest ties: the blocked kernel and
    # the reshaped reference reduce min/max in different fp orders, so w/s
    # can land on opposite sides of a .5 boundary for isolated elements.
    diff = np.abs(np.asarray(wq) - np.asarray(wq_r))
    assert diff.max() <= 1.0
    assert (diff > 0).mean() < 5e-3, f"too many tie mismatches: {(diff > 0).mean()}"
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(z_r))


@settings(max_examples=15, deadline=None)
@given(n=dims_n, g=group_sz, G=ngroups, bits=bits_st, seed=st.integers(0, 2**31))
def test_rtn_error_bound_and_code_range(n, g, G, bits, seed):
    """|W − Ŵ| ≤ s/2 inside the clamp range; codes lie in [0, 2^b − 1]."""
    rng = np.random.default_rng(seed)
    w = _rand(rng, n, g * G)
    wq, s, z = quantize_rtn(w, bits, g)
    wq_np = np.asarray(wq)
    assert wq_np.min() >= 0 and wq_np.max() <= 2**bits - 1
    assert np.allclose(wq_np, np.round(wq_np))  # exact integer codes
    what = np.asarray(ref.dequant_ref(wq, s, z))
    # The asymmetric RTN grid covers [min, max] of each group up to the
    # zero-point rounding, which can shift the grid by ≤ s/2: total ≤ s.
    err = np.abs(np.asarray(w) - what).reshape(n, G, g).max(axis=2)
    assert (err <= np.asarray(s) * 1.0 + 1e-6).all()


@settings(max_examples=12, deadline=None)
@given(n=dims_n, g=group_sz, G=ngroups, bits=bits_st, seed=st.integers(0, 2**31))
def test_rtn_idempotent(n, g, G, bits, seed):
    """Quantizing a dequantized model returns the identical integer matrix."""
    rng = np.random.default_rng(seed)
    w = _rand(rng, n, g * G)
    wq, s, z = quantize_rtn(w, bits, g)
    what = ref.dequant_ref(wq, s, z)
    wq2, s2, z2 = quantize_rtn(what, bits, g)
    what2 = ref.dequant_ref(wq2, s2, z2)
    np.testing.assert_allclose(np.asarray(what2), np.asarray(what), atol=1e-5)


@settings(max_examples=18, deadline=None)
@given(
    B=batch_st, n=dims_n, g=group_sz, G=ngroups, bits=bits_st,
    bb=blocks, bn=blocks, seed=st.integers(0, 2**31),
)
def test_qmatmul_matches_ref(B, n, g, G, bits, bb, bn, seed):
    rng = np.random.default_rng(seed)
    m = g * G
    w = _rand(rng, n, m)
    x = _rand(rng, B, m)
    wq, s, z = quantize_rtn(w, bits, g)
    y = qmatmul(x, wq, s, z, block_b=bb, block_n=bn)
    y_ref = ref.qmatmul_ref(x, wq, s, z)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=18, deadline=None)
@given(
    B=batch_st, n=dims_n, g=group_sz, G=ngroups, bits=bits_st,
    bb=blocks, bn=blocks, seed=st.integers(0, 2**31),
)
def test_qmatmul_t_matches_ref(B, n, g, G, bits, bb, bn, seed):
    rng = np.random.default_rng(seed)
    m = g * G
    w = _rand(rng, n, m)
    dy = _rand(rng, B, n)
    wq, s, z = quantize_rtn(w, bits, g)
    dx = qmatmul_t(dy, wq, s, z, block_b=bb, block_n=bn)
    dx_ref = ref.qmatmul_t_ref(dy, wq, s, z)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=18, deadline=None)
@given(
    B=batch_st, n=dims_n, g=group_sz, G=ngroups, bits=bits_st,
    bn=blocks, seed=st.integers(0, 2**31),
)
def test_peqa_grad_matches_ref(B, n, g, G, bits, bn, seed):
    rng = np.random.default_rng(seed)
    m = g * G
    w = _rand(rng, n, m)
    x = _rand(rng, B, m)
    dy = _rand(rng, B, n)
    wq, s, z = quantize_rtn(w, bits, g)
    ds, dz = peqa_grad(dy, x, wq, s, z, block_n=bn)
    ds_r, dz_r, _ = ref.peqa_grad_ref(dy, x, wq, s, z)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_r), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(dz), np.asarray(dz_r), rtol=1e-3, atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(
    B=batch_st, n=dims_n, g=group_sz, G=ngroups, seed=st.integers(0, 2**31),
)
def test_peqa_grad_matches_autodiff(B, n, g, G, seed):
    """The fused kernel equals jax.grad of the dequantized forward."""
    import jax

    rng = np.random.default_rng(seed)
    m = g * G
    w = _rand(rng, n, m)
    x = _rand(rng, B, m)
    dy = _rand(rng, B, n)
    wq, s, z = quantize_rtn(w, 4, g)

    def fwd(s_, z_):
        return jnp.vdot(dy, ref.qmatmul_ref(x, wq, s_, z_))

    ds_ad, dz_ad = jax.grad(fwd, argnums=(0, 1))(s, z)
    ds, dz = peqa_grad(dy, x, wq, s, z)
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_ad), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(dz), np.asarray(dz_ad), rtol=1e-3, atol=1e-3)


def test_qmatmul_bf16():
    """bf16 activations round-trip through the kernel (loose tolerance)."""
    rng = np.random.default_rng(7)
    w = _rand(rng, 32, 64)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32), dtype=jnp.bfloat16)
    wq, s, z = quantize_rtn(w, 4, 16)
    y = qmatmul(x, wq.astype(jnp.bfloat16), s.astype(jnp.bfloat16), z.astype(jnp.bfloat16))
    y_ref = ref.qmatmul_ref(
        x.astype(jnp.float32), wq, s, z
    )
    np.testing.assert_allclose(
        np.asarray(y, dtype=np.float32), np.asarray(y_ref), rtol=0.1, atol=0.5
    )


def test_pick_block():
    assert pick_block(256, 128) == 128
    assert pick_block(96, 128) == 96
    assert pick_block(96, 64) == 48
    assert pick_block(7, 4) == 1
    assert pick_block(24, 16) == 12


@pytest.mark.parametrize("bits", [3, 4])
def test_degenerate_constant_group(bits):
    """All-equal groups must not divide by zero and must reconstruct exactly."""
    w = jnp.full((4, 16), 0.75, dtype=jnp.float32)
    wq, s, z = quantize_rtn(w, bits, 8)
    what = ref.dequant_ref(wq, s, z)
    np.testing.assert_allclose(np.asarray(what), np.asarray(w), atol=1e-5)
