"""L2 model tests: shapes, method equivalences, gradient flow."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import methods, train as T
from compile.configs import SIZES
from compile.kernels import ref
from compile.model import (
    LORA_QKVO16, LORA_QV4, MethodConfig, ModelConfig, forward, mean_nll,
)

CFG = SIZES["n1"]
OPT_CFG = SIZES["o1"]
FP = MethodConfig(kind="full")


@pytest.fixture(scope="module")
def fp_params():
    return methods.init_params(CFG, FP, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, CFG.seq_len), 0, CFG.vocab)
    mask = jnp.ones((4, CFG.seq_len - 1))
    return tokens, mask


def test_forward_shape(fp_params, batch):
    tokens, _ = batch
    logits = forward(CFG, FP, fp_params, tokens)
    assert logits.shape == (4, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_untrained_nll_near_uniform(fp_params, batch):
    tokens, mask = batch
    nll = float(mean_nll(CFG, FP, fp_params, tokens, mask))
    assert abs(nll - np.log(CFG.vocab)) < 0.1


def test_causality(fp_params):
    """Changing a suffix token must not affect earlier logits."""
    t1 = jax.random.randint(jax.random.PRNGKey(2), (1, CFG.seq_len), 0, CFG.vocab)
    t2 = t1.at[0, -1].set((t1[0, -1] + 7) % CFG.vocab)
    l1 = forward(CFG, FP, fp_params, t1)
    l2 = forward(CFG, FP, fp_params, t2)
    np.testing.assert_allclose(
        np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), atol=1e-5
    )


def test_opt_family_forward():
    params = methods.init_params(OPT_CFG, FP, jax.random.PRNGKey(3))
    tokens = jax.random.randint(
        jax.random.PRNGKey(4), (2, OPT_CFG.seq_len), 0, OPT_CFG.vocab
    )
    logits = forward(OPT_CFG, FP, params, tokens)
    assert logits.shape == (2, OPT_CFG.seq_len, OPT_CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("bits,group", [(4, None), (3, None), (4, 16)])
def test_peqa_equals_dequantized_fp(fp_params, batch, bits, group):
    """PEQA forward == fp forward over the dequantized weights (exactly the
    claim that lets eval artifacts use the fp layout for every method)."""
    tokens, _ = batch
    pm = MethodConfig(kind="peqa", bits=bits, group=group)
    pq = methods.to_peqa(CFG, pm, fp_params)
    deq = dict(fp_params)
    for lp in methods.linear_prefixes(CFG):
        deq[f"{lp}.w"] = ref.dequant_ref(pq[f"{lp}.wq"], pq[f"{lp}.s"], pq[f"{lp}.z"])
    l_peqa = forward(CFG, pm, pq, tokens)
    l_fp = forward(CFG, FP, deq, tokens)
    np.testing.assert_allclose(
        np.asarray(l_peqa), np.asarray(l_fp), rtol=1e-4, atol=1e-4
    )


def test_lora_zero_init_is_identity(fp_params, batch):
    """Fresh LoRA (B = 0) must reproduce the base model exactly."""
    tokens, _ = batch
    lr = methods.to_lora(CFG, LORA_QV4, fp_params, jax.random.PRNGKey(5))
    l_lora = forward(CFG, LORA_QV4, lr, tokens)
    l_fp = forward(CFG, FP, fp_params, tokens)
    np.testing.assert_allclose(np.asarray(l_lora), np.asarray(l_fp), atol=1e-5)


def test_lora_merge_equivalence(fp_params, batch):
    """merge_lora(W, A, B) must reproduce the adapted model."""
    tokens, _ = batch
    key = jax.random.PRNGKey(6)
    lr = methods.to_lora(CFG, LORA_QKVO16, fp_params, key)
    # Give B a nonzero value so the adapters actually do something.
    for lp in methods.linear_prefixes(CFG):
        if f"{lp}.lora_b" in lr:
            key, k = jax.random.split(key)
            lr[f"{lp}.lora_b"] = 0.02 * jax.random.normal(k, lr[f"{lp}.lora_b"].shape)
    merged = methods.merge_lora(CFG, LORA_QKVO16, lr)
    l_ad = forward(CFG, LORA_QKVO16, lr, tokens)
    l_merged = forward(CFG, FP, merged, tokens)
    np.testing.assert_allclose(
        np.asarray(l_ad), np.asarray(l_merged), rtol=1e-4, atol=1e-4
    )


def test_alpha_reconstruction_error_decreases_with_bits(fp_params):
    w = fp_params["layers.0.attn.q.w"]
    errs = []
    for bits in (1, 2, 3, 4):
        from compile.peqa import bcq_dequant, bcq_quantize

        alpha, code = bcq_quantize(w, bits)
        errs.append(float(jnp.linalg.norm(w - bcq_dequant(alpha, code))))
    assert errs == sorted(errs, reverse=True), errs
    assert errs[3] < 0.35 * errs[0]


def test_param_table_roles():
    """Trainable sets per method match the paper's Table 1 taxonomy."""
    t_full = methods.param_table(CFG, FP)
    assert all(p.trainable for p in t_full)

    pm = MethodConfig(kind="peqa", bits=4)
    t_peqa = methods.param_table(CFG, pm)
    trainable = [p.name for p in t_peqa if p.trainable]
    assert trainable and all(n.endswith(".s") for n in trainable)

    zp = MethodConfig(kind="peqa", bits=4, train_scales=False, train_zeros=True)
    t_zp = methods.param_table(CFG, zp)
    assert all(p.name.endswith(".z") for p in t_zp if p.trainable)

    t_lora = methods.param_table(CFG, LORA_QV4)
    tl = [p.name for p in t_lora if p.trainable]
    assert tl and all(("lora_a" in n or "lora_b" in n) for n in tl)
    assert sum("lora_a" in n for n in tl) == 2 * CFG.n_layers  # q and v only


def test_peqa_trainable_count_less_than_lora():
    """Paper Table 4: PEQA (per-channel) has fewer learnable params than
    LoRA QV4 for every llama-family size."""
    for name, cfg in SIZES.items():
        if cfg.family != "llama":
            continue
        pm = MethodConfig(kind="peqa", bits=4)
        n_peqa = sum(
            int(np.prod(p.shape))
            for p in methods.param_table(cfg, pm) if p.trainable
        )
        n_lora = sum(
            int(np.prod(p.shape))
            for p in methods.param_table(cfg, LORA_QV4) if p.trainable
        )
        assert n_peqa < n_lora, (name, n_peqa, n_lora)


def test_grads_only_reach_trainable(fp_params, batch):
    """jax.grad through the PEQA custom_vjp: scales get nonzero grads; the
    integer matrix would get exact zeros (it is excluded by construction)."""
    tokens, mask = batch
    pm = MethodConfig(kind="peqa", bits=4)
    pq = methods.to_peqa(CFG, pm, fp_params)
    tr_specs, fz_specs = methods.split_roles(methods.param_table(CFG, pm))
    tr = methods.pack(tr_specs, pq)
    fz = methods.pack(fz_specs, pq)

    def loss_of(tr_list):
        Pd = methods.unpack(tr_specs, tr_list) | methods.unpack(fz_specs, fz)
        return mean_nll(CFG, pm, Pd, tokens, mask)

    grads = jax.grad(loss_of)(tr)
    assert all(bool(jnp.any(g != 0)) for g in grads)

    # And wq really is frozen: include it and check its grad is exactly 0.
    def loss_wq(wq0):
        Pd = dict(pq)
        Pd["layers.0.attn.q.wq"] = wq0
        return mean_nll(CFG, pm, Pd, tokens, mask)

    gwq = jax.grad(loss_wq)(pq["layers.0.attn.q.wq"])
    assert float(jnp.max(jnp.abs(gwq))) == 0.0


def test_hessian_taps_match_forward(fp_params, batch):
    """make_hessians re-implements the forward with taps; its Hessians must
    be PSD and consistent with an activation-capture reference."""
    tokens, _ = batch
    fn, table = T.make_hessians(CFG)
    hs = fn(tokens, *methods.pack(table, fp_params))
    names = T.hessian_names(CFG)
    assert len(hs) == len(names)
    for h in hs:
        assert h.shape[0] == h.shape[1]
        np.testing.assert_allclose(np.asarray(h), np.asarray(h).T, atol=1e-3)
        eig = np.linalg.eigvalsh(np.asarray(h, dtype=np.float64))
        assert eig.min() > -1e-2, eig.min()
    # qkv Hessian of layer 0 == Gram matrix of ln1 output, computed directly.
    from compile import model as M

    x = fp_params["embed"][tokens]
    h_in = M._rms_norm(x, fp_params["layers.0.ln1.g"])
    a2 = np.asarray(h_in).reshape(-1, CFG.d_model)
    np.testing.assert_allclose(
        np.asarray(hs[0]), a2.T @ a2, rtol=5e-3, atol=5e-3
    )
