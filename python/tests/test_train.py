"""Training-step tests: every method's in-graph AdamW reduces the loss and
only updates what it is supposed to update."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import methods, train as T
from compile.configs import SIZES
from compile.model import LORA_QV4, MethodConfig

CFG = SIZES["n1"]
FP = MethodConfig(kind="full")


def _data(key, batches=6, batch=8):
    """A learnable synthetic stream: tokens follow t+1 = (3t + 7) mod V with
    noise, so a couple of AdamW steps visibly reduce the NLL."""
    ks = jax.random.split(key, batches)
    out = []
    for k in ks:
        start = jax.random.randint(k, (batch, 1), 0, CFG.vocab)
        seq = [start]
        for _ in range(CFG.seq_len - 1):
            seq.append((3 * seq[-1] + 7) % CFG.vocab)
        tokens = jnp.concatenate(seq, axis=1)
        out.append(tokens.astype(jnp.int32))
    return out


def _init_for(mcfg, key=jax.random.PRNGKey(0)):
    fp = methods.init_params(CFG, FP, key)
    if mcfg.kind in ("full", "qat"):
        return fp
    if mcfg.kind == "lora":
        return methods.to_lora(CFG, mcfg, fp, jax.random.PRNGKey(9))
    if mcfg.kind == "peqa":
        return methods.to_peqa(CFG, mcfg, fp)
    if mcfg.kind == "alpha":
        return methods.to_alpha(CFG, mcfg, fp)
    raise ValueError(mcfg.kind)


def _run_steps(mcfg, n_steps=6, lr=5e-3):
    params = _init_for(mcfg)
    fn, tr_specs, fz_specs = T.make_train_step(CFG, mcfg)
    jfn = jax.jit(fn)
    tr = methods.pack(tr_specs, params)
    fz = methods.pack(fz_specs, params)
    m = [jnp.zeros(p.shape) for p in tr_specs]
    v = [jnp.zeros(p.shape) for p in tr_specs]
    mask = jnp.ones((8, CFG.seq_len - 1))
    losses = []
    for i, tokens in enumerate(_data(jax.random.PRNGKey(42), batches=n_steps)):
        out = jfn(tokens, mask, jnp.float32(lr), jnp.float32(i + 1), *tr, *fz, *m, *v)
        nt = len(tr)
        losses.append(float(out[0]))
        tr = list(out[1 : 1 + nt])
        m = list(out[1 + nt : 1 + 2 * nt])
        v = list(out[1 + 2 * nt : 1 + 3 * nt])
    return losses, tr, fz, tr_specs, fz_specs


METHODS = [
    MethodConfig(kind="full"),
    LORA_QV4,
    MethodConfig(kind="qat", bits=4),
    MethodConfig(kind="peqa", bits=4),
    MethodConfig(kind="peqa", bits=3),
    MethodConfig(kind="peqa", bits=4, group=16),
    MethodConfig(kind="peqa", bits=4, train_scales=True, train_zeros=True),
    MethodConfig(kind="alpha", bits=4),
]


@pytest.mark.parametrize("mcfg", METHODS, ids=lambda m: m.tag())
def test_loss_decreases(mcfg):
    # LoRA starts at B = 0, so A receives zero gradient on the first step
    # (dL/dA = Bᵀ·…) and needs more steps + the larger lr the paper also
    # uses for LoRA (appendix C) before the loss visibly moves.
    if mcfg.kind == "lora":
        losses, *_ = _run_steps(mcfg, n_steps=25, lr=5e-2)
    else:
        losses, *_ = _run_steps(mcfg)
    assert losses[-1] < losses[0] - 0.05, losses


def test_frozen_stay_bitwise_identical():
    """The train step returns only trainable/m/v — frozen tensors are inputs
    only, so they are bitwise-stable by construction; additionally the
    integer codes must remain exact integers after any number of steps."""
    mcfg = MethodConfig(kind="peqa", bits=4)
    losses, tr, fz, tr_specs, fz_specs = _run_steps(mcfg)
    for spec, val in zip(fz_specs, fz):
        if spec.name.endswith(".wq"):
            arr = np.asarray(val)
            assert np.array_equal(arr, np.round(arr))
            assert arr.min() >= 0 and arr.max() <= 15


def test_scales_actually_move():
    mcfg = MethodConfig(kind="peqa", bits=4)
    params = _init_for(mcfg)
    losses, tr, fz, tr_specs, _ = _run_steps(mcfg)
    moved = 0
    for spec, new in zip(tr_specs, tr):
        old = params[spec.name]
        if bool(jnp.any(jnp.abs(new - old) > 1e-7)):
            moved += 1
    assert moved == len(tr_specs)


def test_adamw_matches_reference_formula():
    """One in-graph AdamW step == hand-computed numpy update."""
    p = jnp.asarray([1.0, -2.0, 0.5])
    g = jnp.asarray([0.1, -0.2, 0.3])
    m0 = jnp.asarray([0.01, 0.0, -0.02])
    v0 = jnp.asarray([0.001, 0.002, 0.0])
    lr, wd, step = 1e-2, 0.1, 3.0
    pn, mn, vn = T.adamw_update(p, g, m0, v0, step, lr, wd)
    b1, b2, eps = T.ADAM_B1, T.ADAM_B2, T.ADAM_EPS
    m_ref = b1 * np.asarray(m0) + (1 - b1) * np.asarray(g)
    v_ref = b2 * np.asarray(v0) + (1 - b2) * np.asarray(g) ** 2
    mh = m_ref / (1 - b1**step)
    vh = v_ref / (1 - b2**step)
    p_ref = np.asarray(p) - lr * (mh / (np.sqrt(vh) + eps) + wd * np.asarray(p))
    np.testing.assert_allclose(np.asarray(pn), p_ref, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(mn), m_ref, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(vn), v_ref, rtol=1e-6)


def test_loss_mask_zeroes_positions():
    """A zero mask over the first half must change the loss value."""
    mcfg = MethodConfig(kind="full")
    params = _init_for(mcfg)
    fn_eval, table = T.make_eval(CFG)
    tokens = _data(jax.random.PRNGKey(3), batches=1)[0]
    full_mask = jnp.ones((8, CFG.seq_len - 1))
    half_mask = full_mask.at[:, : CFG.seq_len // 2].set(0.0)
    flat = methods.pack(table, params)
    s1, c1 = fn_eval(tokens, full_mask, *flat)
    s2, c2 = fn_eval(tokens, half_mask, *flat)
    assert float(c2) == pytest.approx(float(c1) - 8 * (CFG.seq_len // 2))
    assert float(s2) < float(s1)


def test_prep_roundtrip_peqa():
    """prep artifact fn: fp flat list → peqa flat list, matching to_peqa."""
    mcfg = MethodConfig(kind="peqa", bits=4)
    fp = methods.init_params(CFG, FP, jax.random.PRNGKey(1))
    fn, fp_table, out_table = T.make_prep(CFG, mcfg)
    out = fn(*methods.pack(fp_table, fp))
    direct = methods.to_peqa(CFG, mcfg, fp)
    for spec, val in zip(out_table, out):
        np.testing.assert_allclose(
            np.asarray(val), np.asarray(direct[spec.name]), rtol=1e-5, atol=1e-6,
            err_msg=spec.name,
        )
