"""Build-time compile package for the PEQA reproduction.

Python here runs ONCE (``make artifacts``) to author and AOT-lower the
L2 jax model (with L1 Pallas kernels inside) to HLO text artifacts the
rust runtime loads via PJRT. Nothing in this package runs at request time.
"""
