"""In-graph training step, evaluation and calibration functions.

Everything here is built to be AOT-lowered: each builder returns a pure
function over flat positional tensor arguments (order defined by
methods.param_table) so the HLO parameter order is unambiguous for the
rust runtime. The optimizer (AdamW, appendix A) runs *inside* the graph;
rust owns only the learning-rate schedule and the data pipeline.

Optimizer state exists ONLY for trainable tensors — this is what makes the
Appendix-L memory claims measurable: PEQA's m/v buffers are scale-sized,
LoRA's are adapter-sized, full FT's are model-sized.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .methods import pack, param_table, split_roles, unpack
from .model import MethodConfig, ModelConfig, forward, mean_nll, nll

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def adamw_update(p, g, m, v, step, lr, weight_decay=0.0):
    """One decoupled-weight-decay Adam update (Loshchilov & Hutter)."""
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1**step)
    vhat = v / (1.0 - ADAM_B2**step)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + weight_decay * p)
    return p, m, v


def make_train_step(cfg: ModelConfig, mcfg: MethodConfig, weight_decay: float = 0.0):
    """-> (fn, train_specs, frozen_specs).

    fn(tokens (B,T) i32, mask (B,T−1) f32, lr () f32, step () f32,
       *trainable, *frozen, *m, *v)
      -> (loss (), *new_trainable, *new_m, *new_v)
    """
    table = param_table(cfg, mcfg)
    train_specs, frozen_specs = split_roles(table)
    nt, nf = len(train_specs), len(frozen_specs)

    def fn(tokens, mask, lr, step, *flat):
        trainable = list(flat[:nt])
        frozen = list(flat[nt : nt + nf])
        m = list(flat[nt + nf : 2 * nt + nf])
        v = list(flat[2 * nt + nf : 3 * nt + nf])

        def loss_of(tr):
            Pd = unpack(train_specs, tr) | unpack(frozen_specs, frozen)
            return mean_nll(cfg, mcfg, Pd, tokens, mask)

        loss, grads = jax.value_and_grad(loss_of)(trainable)
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(trainable, grads, m, v):
            pn, mn, vn = adamw_update(p, g, mi, vi, step, lr, weight_decay)
            new_p.append(pn)
            new_m.append(mn)
            new_v.append(vn)
        return tuple([loss] + new_p + new_m + new_v)

    return fn, train_specs, frozen_specs


def make_eval(cfg: ModelConfig):
    """Masked NLL over a batch, fp param layout (methods dequantize into it).

    fn(tokens (B,T) i32, mask (B,T−1) f32, *params) -> (sum_nll, n_tokens)
    """
    mcfg = MethodConfig(kind="full")
    table = param_table(cfg, mcfg)

    def fn(tokens, mask, *flat):
        Pd = unpack(table, list(flat))
        return nll(cfg, mcfg, Pd, tokens, mask)

    return fn, table


def make_logits(cfg: ModelConfig):
    """Full-context logits, fp layout. fn(tokens, *params) -> logits (B,T,V)."""
    mcfg = MethodConfig(kind="full")
    table = param_table(cfg, mcfg)

    def fn(tokens, *flat):
        return (forward(cfg, mcfg, unpack(table, list(flat)), tokens),)

    return fn, table


def make_logits_q(cfg: ModelConfig, mcfg: MethodConfig):
    """Quantized-layout logits — the serving path through the Pallas
    dequant-matmul kernels. fn(tokens, *params) -> logits (B,T,V)."""
    table = param_table(cfg, mcfg)

    def fn(tokens, *flat):
        return (forward(cfg, mcfg, unpack(table, list(flat)), tokens),)

    return fn, table


def make_hessians(cfg: ModelConfig):
    """Per-projection-family Hessian accumulators for OPTQ calibration.

    H = Σ_t x_t x_tᵀ over every token position, for each distinct linear
    *input* inside each block:

      llama: [qkv (d,d), o (d,d), gateup (d,d), down (ff,ff)] × n_layers
      opt:   [qkv (d,d), o (d,d), fc1 (d,d), fc2 (ff,ff)]     × n_layers

    fn(tokens (B,T) i32, *fp params) -> tuple of 4·L matrices. Rust sums
    these across calibration batches and hands them to quant::optq.
    """
    mcfg = MethodConfig(kind="full")
    table = param_table(cfg, mcfg)

    # Re-implement the forward but tap every linear input. Kept in lock-step
    # with model.forward; test_model.py asserts the taps don't perturb logits.
    from . import model as M

    def fn(tokens, *flat):
        Pd = unpack(table, list(flat))
        B, T = tokens.shape
        x = Pd["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        if cfg.family == "opt":
            x = x + Pd["pos_embed"][:T][None]
        hessians = []

        def hess(a):  # a: (B, T, m) -> (m, m)
            a2 = a.reshape(-1, a.shape[-1])
            return a2.T @ a2

        for i in range(cfg.n_layers):
            lp = f"layers.{i}"
            h_in = M._norm(cfg, Pd, f"{lp}.ln1", x)
            hessians.append(hess(h_in))  # qkv family
            H, hd = cfg.n_heads, cfg.head_dim
            q = M._linear(mcfg, Pd, f"{lp}.attn.q", h_in).reshape(B, T, H, hd)
            k = M._linear(mcfg, Pd, f"{lp}.attn.k", h_in).reshape(B, T, H, hd)
            v = M._linear(mcfg, Pd, f"{lp}.attn.v", h_in).reshape(B, T, H, hd)
            if cfg.family == "llama":
                q, k = M._rope(q, positions), M._rope(k, positions)
            att = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(float(hd))
            causal = jnp.tril(jnp.ones((T, T), dtype=bool))
            att = jax.nn.softmax(jnp.where(causal[None, None], att, -1e30), axis=-1)
            o_in = jnp.einsum("bhts,bshd->bthd", att, v).reshape(B, T, cfg.d_model)
            hessians.append(hess(o_in))  # o family
            x = x + M._linear(mcfg, Pd, f"{lp}.attn.o", o_in)
            m_in = M._norm(cfg, Pd, f"{lp}.ln2", x)
            hessians.append(hess(m_in))  # gate/up (llama) or fc1 (opt)
            if cfg.family == "llama":
                gate = M._linear(mcfg, Pd, f"{lp}.mlp.gate", m_in)
                up = M._linear(mcfg, Pd, f"{lp}.mlp.up", m_in)
                d_in = jax.nn.silu(gate) * up
                hessians.append(hess(d_in))  # down family
                x = x + M._linear(mcfg, Pd, f"{lp}.mlp.down", d_in)
            else:
                d_in = jax.nn.gelu(M._linear(mcfg, Pd, f"{lp}.mlp.fc1", m_in))
                hessians.append(hess(d_in))  # fc2 family
                x = x + M._linear(mcfg, Pd, f"{lp}.mlp.fc2", d_in)
        return tuple(hessians)

    return fn, table


def make_prep(cfg: ModelConfig, mcfg: MethodConfig):
    """Checkpoint transform artifact: fp layout → method layout.

    fn(*fp params) -> (*method params). Runs the Pallas RTN kernel (peqa)
    or BCQ (alpha) on-device so rust can re-quantize a fine-tuned
    checkpoint without Python. LoRA needs no prep: its adapters are pure
    init-spec tensors the rust side creates (normal/zeros).
    """
    from .methods import to_alpha, to_peqa

    fp_table = param_table(cfg, MethodConfig(kind="full"))
    out_table = param_table(cfg, mcfg)

    def fn(*flat):
        fp = unpack(fp_table, list(flat))
        if mcfg.kind == "peqa":
            out = to_peqa(cfg, mcfg, fp)
        elif mcfg.kind == "alpha":
            out = to_alpha(cfg, mcfg, fp)
        else:
            raise ValueError(f"no prep for method {mcfg.kind}")
        return tuple(pack(out_table, out))

    return fn, fp_table, out_table


def hessian_names(cfg: ModelConfig) -> list[str]:
    """Output naming for make_hessians, aligned with its tuple order."""
    fams = ["qkv", "o", "gateup", "down"] if cfg.family == "llama" else [
        "qkv", "o", "fc1", "fc2"
    ]
    return [f"layers.{i}.hess.{f}" for i in range(cfg.n_layers) for f in fams]
