"""L2 — the transformer language model every experiment runs on.

Two architecture families (DESIGN §Substitutions):

  • ``llama``: RMSNorm, rotary positions, SwiGLU MLP — the analog of the
    GPT-Neo/GPT-J/LLaMA models of Tables 2/3/5/6/7.
  • ``opt``:   LayerNorm(+bias), learned positions, GELU MLP — the analog
    of the OPT family of Table 10 / Appendix E.

The seven projections per block (q,k,v,o + the MLP's 2–3) are "linears"
whose representation depends on the fine-tuning method (methods.py):
raw fp (full/LoRA/QAT), PEQA (wq, s, z), or BCQ (alpha, codes). Embeddings,
norms and the LM head stay fp — matching the paper, which quantizes the
fully-connected layers of the blocks.

Params are a flat dict keyed by dotted names ("layers.0.attn.q.w"); the
canonical ordering lives in methods.param_table and is exported to the
rust side through each artifact's meta.json.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import peqa as P


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (also serialized into meta.json)."""

    name: str
    family: str = "llama"       # "llama" | "opt"
    vocab: int = 512
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 192
    seq_len: int = 64           # training/eval context length
    tie_head: bool = False

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def mlp_names(self):
        return ("gate", "up", "down") if self.family == "llama" else ("fc1", "fc2")

    def linear_shapes(self) -> dict[str, tuple[int, int]]:
        """(out, in) shapes of the quantizable projections of one block."""
        d, f = self.d_model, self.d_ff
        shapes = {"attn.q": (d, d), "attn.k": (d, d), "attn.v": (d, d), "attn.o": (d, d)}
        if self.family == "llama":
            shapes.update({"mlp.gate": (f, d), "mlp.up": (f, d), "mlp.down": (d, f)})
        else:
            shapes.update({"mlp.fc1": (f, d), "mlp.fc2": (d, f)})
        return shapes

    def n_params(self) -> int:
        """Total parameter count of the fp model (for Table 4 accounting)."""
        per_block = sum(n * m for n, m in self.linear_shapes().values())
        per_block += 2 * self.d_model                       # two norms
        if self.family == "opt":
            per_block += 2 * self.d_model                   # norm biases
        total = self.n_layers * per_block
        total += self.vocab * self.d_model                  # embedding
        if not self.tie_head:
            total += self.vocab * self.d_model              # lm head
        total += self.d_model                               # final norm
        if self.family == "opt":
            total += self.seq_len * self.d_model + self.d_model  # pos emb + bias
        return total


@dataclass(frozen=True)
class MethodConfig:
    """How the block projections are represented / which params train."""

    kind: str = "full"          # full | lora | qat | peqa | alpha
    bits: int = 4               # qat/peqa/alpha
    group: int | None = None    # None = per-channel
    # peqa ablation (Table 17): train scales, zero-points, or both
    train_scales: bool = True
    train_zeros: bool = False
    # lora
    rank: int = 4
    lora_targets: tuple[str, ...] = ("attn.q", "attn.v")
    lora_alpha: float = 8.0

    def tag(self) -> str:
        if self.kind == "full":
            return "full"
        if self.kind == "lora":
            t = "qv" if self.lora_targets == ("attn.q", "attn.v") else "qkvo"
            return f"lora_{t}{self.rank}"
        g = "gc" if self.group is None else f"g{self.group}"
        if self.kind == "peqa":
            v = {(True, False): "", (False, True): "_zp", (True, True): "_szp"}[
                (self.train_scales, self.train_zeros)
            ]
            return f"peqa{v}_b{self.bits}_{g}"
        return f"{self.kind}_b{self.bits}_{g}"


LORA_QV4 = MethodConfig(kind="lora", rank=4, lora_targets=("attn.q", "attn.v"))
LORA_QKVO16 = MethodConfig(
    kind="lora", rank=16, lora_targets=("attn.q", "attn.k", "attn.v", "attn.o"),
    lora_alpha=32.0,
)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _rms_norm(x, g, eps=1e-6):
    return g * x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + eps) + b


def _rope(x, positions):
    """Rotary embedding over the last dim of x: (B, T, H, hd)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[:, :, None, None].astype(jnp.float32) * freqs  # (B,T,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _norm(cfg, Pd, prefix, x):
    if cfg.family == "llama":
        return _rms_norm(x, Pd[f"{prefix}.g"])
    return _layer_norm(x, Pd[f"{prefix}.g"], Pd[f"{prefix}.b"])


def _linear(mcfg: MethodConfig, Pd, prefix: str, x):
    """Apply one quantizable projection in its method representation."""
    k = mcfg.kind
    if k in ("full",):
        return x @ Pd[f"{prefix}.w"].T
    if k == "qat":
        return P.qat_linear(x, Pd[f"{prefix}.w"], mcfg.bits, mcfg.group)
    if k == "lora":
        y = x @ Pd[f"{prefix}.w"].T
        if f"{prefix}.lora_a" in Pd:
            a, b = Pd[f"{prefix}.lora_a"], Pd[f"{prefix}.lora_b"]
            y = y + (x @ a.T) @ b.T * (mcfg.lora_alpha / mcfg.rank)
        return y
    if k == "peqa":
        return P.peqa_linear(x, Pd[f"{prefix}.wq"], Pd[f"{prefix}.s"], Pd[f"{prefix}.z"])
    if k == "alpha":
        # α is stored split so only the first column trains (Table 15).
        alpha = jnp.concatenate(
            [Pd[f"{prefix}.alpha1"], Pd[f"{prefix}.alpha_rest"]], axis=1
        )
        return P.alphatuning_linear(x, alpha, Pd[f"{prefix}.code"])
    raise ValueError(f"unknown method kind {k}")


def _attention(cfg, mcfg, Pd, lp, x, positions):
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = _linear(mcfg, Pd, f"{lp}.attn.q", x).reshape(B, T, H, hd)
    k = _linear(mcfg, Pd, f"{lp}.attn.k", x).reshape(B, T, H, hd)
    v = _linear(mcfg, Pd, f"{lp}.attn.v", x).reshape(B, T, H, hd)
    if cfg.family == "llama":
        q, k = _rope(q, positions), _rope(k, positions)
    att = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    att = jnp.where(causal[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", att, v).reshape(B, T, d)
    return _linear(mcfg, Pd, f"{lp}.attn.o", out)


def _mlp(cfg, mcfg, Pd, lp, x):
    if cfg.family == "llama":
        gate = _linear(mcfg, Pd, f"{lp}.mlp.gate", x)
        up = _linear(mcfg, Pd, f"{lp}.mlp.up", x)
        return _linear(mcfg, Pd, f"{lp}.mlp.down", jax.nn.silu(gate) * up)
    h = jax.nn.gelu(_linear(mcfg, Pd, f"{lp}.mlp.fc1", x))
    return _linear(mcfg, Pd, f"{lp}.mlp.fc2", h)


def forward(cfg: ModelConfig, mcfg: MethodConfig, Pd: dict, tokens):
    """tokens (B, T) int32 → logits (B, T, vocab) float32."""
    B, T = tokens.shape
    x = Pd["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    if cfg.family == "opt":
        x = x + Pd["pos_embed"][:T][None]
    for i in range(cfg.n_layers):
        lp = f"layers.{i}"
        x = x + _attention(cfg, mcfg, Pd, lp, _norm(cfg, Pd, f"{lp}.ln1", x), positions)
        x = x + _mlp(cfg, mcfg, Pd, lp, _norm(cfg, Pd, f"{lp}.ln2", x))
    x = _norm(cfg, Pd, "final_norm", x)
    head = Pd["embed"] if cfg.tie_head else Pd["lm_head"]
    return x @ head.T


def nll(cfg, mcfg, Pd, tokens, loss_mask):
    """Masked next-token NLL.

    tokens (B, T) int32; loss_mask (B, T−1) float32 weighting each predicted
    position. Returns (sum_nll, sum_mask) so callers can form means/PPL.
    """
    logits = forward(cfg, mcfg, Pd, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_logp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.sum(tok_logp * loss_mask), jnp.sum(loss_mask)


def mean_nll(cfg, mcfg, Pd, tokens, loss_mask):
    total, count = nll(cfg, mcfg, Pd, tokens, loss_mask)
    return total / jnp.maximum(count, 1.0)
