"""L1 performance analysis: VMEM-footprint / MXU-utilization / HBM-traffic
model for the qmatmul kernel (DESIGN.md §Hardware-Adaptation, §Perf).

interpret=True gives CPU-numpy timings only — not a TPU proxy — so kernel
*structure* is what we optimize: this tool sweeps tile configurations for
every projection shape in the model family and reports, per shape:

  * the chosen (bb, nb, gb) tile under the 16 MiB VMEM budget,
  * estimated MXU utilization and memory- vs compute-boundness,
  * HBM weight-traffic ratio vs fp16 (the source of the paper's decode
    speedup: 4×/5.33× fewer weight bytes at 4/3-bit).

Usage: python -m compile.perf_report [--bits 4] [--decode]
"""

from __future__ import annotations

import argparse

from .configs import LLAMA_SIZES
from .kernels.util import qmatmul_tile_estimate, VMEM_BYTES


def best_tile(batch: int, n: int, m: int, bits: int):
    """Pick the tile maximizing estimated throughput under the VMEM budget."""
    candidates = []
    for bb in (1, 8, 32, 64, 128, 256, 512):
        if bb > batch:
            continue
        for nb in (64, 128, 256, 512):
            if nb > n:
                continue
            for gb in (64, 128, 256, 512):
                if gb > m:
                    continue
                est = qmatmul_tile_estimate(batch, n, m, bits, bb, nb, gb)
                if est.vmem_bytes <= VMEM_BYTES:
                    candidates.append(((bb, nb, gb), est))
    if not candidates:
        return None, None
    # Prefer the lowest estimated time; tie-break on bigger MXU tiles.
    return min(candidates, key=lambda c: (c[1].est_s, -c[1].mxu_util))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--decode", action="store_true",
                    help="B=1 decode GEMV instead of training GEMM")
    args = ap.parse_args()

    print(f"qmatmul tile report — {args.bits}-bit weights, "
          f"{'decode (B=1)' if args.decode else 'train (B=512 tokens)'}")
    print(f"{'shape':>16} {'tile (bb,nb,gb)':>18} {'VMEM':>10} "
          f"{'MXU util':>9} {'bound':>8} {'W-traffic vs fp16':>18}")
    seen = set()
    for cfg in LLAMA_SIZES.values():
        batch = 1 if args.decode else 8 * cfg.seq_len
        for name, (n, m) in cfg.linear_shapes().items():
            if (n, m) in seen:
                continue
            seen.add((n, m))
            tile, est = best_tile(batch, n, m, args.bits)
            if est is None:
                continue
            bound = "memory" if est.mem_bound_s > est.flop_bound_s else "MXU"
            print(f"{f'{n}x{m}':>16} {str(tile):>18} "
                  f"{est.vmem_bytes/2**20:>8.2f}Mi {est.mxu_util:>8.0%} "
                  f"{bound:>8} {16/args.bits:>17.2f}x")
    # The headline deployment claim: decode is memory-bound, so weight
    # traffic ~ linear in bits → 16/b speedup ceiling at fixed bandwidth.
    print(f"\ndecode weight-bytes ratio fp16 : int{args.bits} = "
          f"{16/args.bits:.2f} : 1  (paper's 'fast inference' column)")


if __name__ == "__main__":
    main()
