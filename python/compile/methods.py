"""Parameter tables, initialization and method transforms.

The single source of truth for *parameter layout*: ``param_table`` walks
the model in a canonical order and emits one ``ParamSpec`` per tensor with
its shape, trainable role and init spec. The same table drives:

  • jax: packing/unpacking the flat argument list of AOT'd functions,
  • meta.json: the ordered param manifest the rust runtime loads,
  • rust: from-scratch init (pretraining) and checkpoint I/O.

Method transforms (fp checkpoint → method representation) live here too;
they are what the ``prep_*`` artifacts execute so the rust side can
quantize without Python.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import peqa as P
from .kernels import quantize_rtn
from .model import MethodConfig, ModelConfig


@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    trainable: bool
    init: str  # "normal:<std>" | "zeros" | "ones" — used for from-scratch init


def _linear_specs(mcfg: MethodConfig, prefix: str, n: int, m: int) -> list[ParamSpec]:
    k = mcfg.kind
    if k in ("full", "qat"):
        return [ParamSpec(f"{prefix}.w", (n, m), True, "normal:0.02")]
    if k == "lora":
        specs = [ParamSpec(f"{prefix}.w", (n, m), False, "normal:0.02")]
        target = prefix.split(".", 2)[-1]  # e.g. "attn.q"
        if target in mcfg.lora_targets:
            specs += [
                ParamSpec(f"{prefix}.lora_a", (mcfg.rank, m), True, "normal:0.01"),
                ParamSpec(f"{prefix}.lora_b", (n, mcfg.rank), True, "zeros"),
            ]
        return specs
    if k == "peqa":
        G = 1 if mcfg.group is None else m // mcfg.group
        return [
            ParamSpec(f"{prefix}.wq", (n, m), False, "zeros"),
            ParamSpec(f"{prefix}.s", (n, G), mcfg.train_scales, "ones"),
            ParamSpec(f"{prefix}.z", (n, G), mcfg.train_zeros, "zeros"),
        ]
    if k == "alpha":
        b = mcfg.bits
        return [
            ParamSpec(f"{prefix}.alpha1", (n, 1), True, "ones"),
            ParamSpec(f"{prefix}.alpha_rest", (n, b - 1), False, "ones"),
            ParamSpec(f"{prefix}.code", (n, m, b), False, "zeros"),
        ]
    raise ValueError(k)


def param_table(cfg: ModelConfig, mcfg: MethodConfig) -> list[ParamSpec]:
    """Canonical ordered parameter manifest for (architecture, method)."""
    base_train = mcfg.kind in ("full", "qat")
    specs: list[ParamSpec] = [
        ParamSpec("embed", (cfg.vocab, cfg.d_model), base_train, "normal:0.02")
    ]
    if cfg.family == "opt":
        specs.append(
            ParamSpec("pos_embed", (cfg.seq_len, cfg.d_model), base_train, "normal:0.02")
        )
    lin = cfg.linear_shapes()
    for i in range(cfg.n_layers):
        lp = f"layers.{i}"
        for ln in ("ln1", "ln2"):
            specs.append(ParamSpec(f"{lp}.{ln}.g", (cfg.d_model,), base_train, "ones"))
            if cfg.family == "opt":
                specs.append(
                    ParamSpec(f"{lp}.{ln}.b", (cfg.d_model,), base_train, "zeros")
                )
        order = ["attn.q", "attn.k", "attn.v", "attn.o"] + [
            f"mlp.{x}" for x in cfg.mlp_names()
        ]
        for key in order:
            n, m = lin[key]
            specs += _linear_specs(mcfg, f"{lp}.{key}", n, m)
    specs.append(ParamSpec("final_norm.g", (cfg.d_model,), base_train, "ones"))
    if cfg.family == "opt":
        specs.append(ParamSpec("final_norm.b", (cfg.d_model,), base_train, "zeros"))
    if not cfg.tie_head:
        specs.append(
            ParamSpec("lm_head", (cfg.vocab, cfg.d_model), base_train, "normal:0.02")
        )
    return specs


def split_roles(table: list[ParamSpec]):
    """-> (trainable specs, frozen specs), preserving canonical order."""
    return [p for p in table if p.trainable], [p for p in table if not p.trainable]


def pack(table: list[ParamSpec], Pd: dict) -> list:
    return [Pd[p.name] for p in table]


def unpack(table: list[ParamSpec], flat: list) -> dict:
    assert len(table) == len(flat)
    return {p.name: a for p, a in zip(table, flat)}


# ---------------------------------------------------------------------------
# Init + transforms
# ---------------------------------------------------------------------------


def init_from_spec(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init.startswith("normal:"):
        std = float(spec.init.split(":")[1])
        return std * jax.random.normal(key, spec.shape, dtype=jnp.float32)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype=jnp.float32)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype=jnp.float32)
    raise ValueError(spec.init)


def init_params(cfg: ModelConfig, mcfg: MethodConfig, key) -> dict:
    table = param_table(cfg, mcfg)
    keys = jax.random.split(key, len(table))
    return {p.name: init_from_spec(p, k) for p, k in zip(table, keys)}


def linear_prefixes(cfg: ModelConfig) -> list[str]:
    """Dotted prefixes of every quantizable projection, canonical order."""
    order = ["attn.q", "attn.k", "attn.v", "attn.o"] + [
        f"mlp.{x}" for x in cfg.mlp_names()
    ]
    return [f"layers.{i}.{k}" for i in range(cfg.n_layers) for k in order]


def to_peqa(cfg: ModelConfig, mcfg: MethodConfig, fp: dict) -> dict:
    """fp checkpoint → PEQA params: quantize every projection (Eq. 1 RTN init),
    copy everything else (frozen)."""
    out = dict(fp)
    for lp in linear_prefixes(cfg):
        w = out.pop(f"{lp}.w")
        wq, s, z = quantize_rtn(w, mcfg.bits, mcfg.group)
        out[f"{lp}.wq"], out[f"{lp}.s"], out[f"{lp}.z"] = wq, s, z
    return out


def to_lora(cfg: ModelConfig, mcfg: MethodConfig, fp: dict, key) -> dict:
    out = dict(fp)
    for lp in linear_prefixes(cfg):
        target = lp.split(".", 2)[-1]
        if target in mcfg.lora_targets:
            n, m = fp[f"{lp}.w"].shape
            key, k1 = jax.random.split(key)
            out[f"{lp}.lora_a"] = 0.01 * jax.random.normal(k1, (mcfg.rank, m))
            out[f"{lp}.lora_b"] = jnp.zeros((n, mcfg.rank))
    return out


def to_alpha(cfg: ModelConfig, mcfg: MethodConfig, fp: dict) -> dict:
    out = dict(fp)
    for lp in linear_prefixes(cfg):
        w = out.pop(f"{lp}.w")
        alpha, code = P.bcq_quantize(w, mcfg.bits)
        out[f"{lp}.alpha1"] = alpha[:, :1]
        out[f"{lp}.alpha_rest"] = alpha[:, 1:]
        out[f"{lp}.code"] = code
    return out


def merge_lora(cfg: ModelConfig, mcfg: MethodConfig, params: dict) -> dict:
    """Fold LoRA adapters back into the base weights (deployment merge)."""
    out = {}
    for name, a in params.items():
        if name.endswith(".lora_a") or name.endswith(".lora_b"):
            continue
        out[name] = a
    for lp in linear_prefixes(cfg):
        if f"{lp}.lora_a" in params:
            a, b = params[f"{lp}.lora_a"], params[f"{lp}.lora_b"]
            out[f"{lp}.w"] = params[f"{lp}.w"] + b @ a * (mcfg.lora_alpha / mcfg.rank)
    return out
