"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written in the most obvious jnp form. pytest (python/tests/test_kernels.py)
sweeps shapes / bit-widths / group sizes with hypothesis and asserts
allclose between kernel and oracle. The oracles are also what the L2 model
uses when ``use_pallas=False`` (the two paths are tested equal, so either
may be AOT-exported).

Quantization convention (paper Eq. 1, asymmetric uniform, per-channel or
per-group along the input dimension):

    W ∈ R^{n×m},  group size g | m,  G = m // g
    s, z ∈ R^{n×G}
    Wq[i,j] = clamp(round(W[i,j]/s[i,j//g]) + z[i,j//g], 0, 2^b - 1)   (stored)
    Ŵ[i,j] = s[i,j//g] · (Wq[i,j] − z[i,j//g])                         (Eq. 1)

The paper's W̄0 is (Wq − z); we store Wq (unsigned codes) and keep z
separate so that the Table-17 ablations (train scales, zero-points, or
both) all read Ŵ = s·(Wq − z) with different trainable subsets.
"""

from __future__ import annotations

import jax.numpy as jnp

# Guard against degenerate groups (max == min): scales are clamped to EPS so
# dequantization never divides by / multiplies with zero.
EPS = 1e-8


def _group(w: jnp.ndarray, group: int) -> jnp.ndarray:
    """Reshape (n, m) -> (n, G, g) view over quantization groups."""
    n, m = w.shape
    assert m % group == 0, f"group {group} must divide m={m}"
    return w.reshape(n, m // group, group)


def quantize_rtn_ref(w: jnp.ndarray, bits: int, group: int | None = None):
    """Round-to-nearest asymmetric quantization (paper Eq. 1 init).

    Args:
      w:     (n, m) float weights.
      bits:  target bit-width b (2..8).
      group: group size along m; ``None`` = per-channel (one group per row).

    Returns:
      (wq, s, z): codes (n, m) float holding integers in [0, 2^b-1],
      scales (n, G) and zero-points (n, G) with G = m // group.
    """
    n, m = w.shape
    group = m if group is None else group
    qmax = float(2**bits - 1)
    wg = _group(w, group)
    # Zero is forced into the representable range (standard asymmetric
    # min/max practice): this keeps z ∈ [0, qmax] by construction and makes
    # constant groups reconstruct exactly instead of degenerating to s=EPS.
    wmin = jnp.minimum(jnp.min(wg, axis=2), 0.0)
    wmax = jnp.maximum(jnp.max(wg, axis=2), 0.0)
    s = jnp.maximum((wmax - wmin) / qmax, EPS)
    z = jnp.clip(jnp.round(-wmin / s), 0.0, qmax)
    codes = jnp.clip(jnp.round(wg / s[:, :, None]) + z[:, :, None], 0.0, qmax)
    return codes.reshape(n, m), s, z


def dequant_ref(wq: jnp.ndarray, s: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Ŵ = s · (Wq − z), broadcasting (n, G) params over groups."""
    n, m = wq.shape
    g = m // s.shape[1]
    what = (_group(wq, g) - z[:, :, None]) * s[:, :, None]
    return what.reshape(n, m)


def qmatmul_ref(x, wq, s, z):
    """y = x @ Ŵᵀ  — the fused dequantize-and-matmul the Pallas kernel does.

    x: (B, m), wq: (n, m), s/z: (n, G)  ->  y: (B, n)
    """
    return x @ dequant_ref(wq, s, z).T


def qmatmul_t_ref(dy, wq, s, z):
    """dx = dy @ Ŵ — transposed product used by the VJP. dy: (B, n) -> (B, m)."""
    return dy @ dequant_ref(wq, s, z)


def group_partials_ref(x, wq, z):
    """u[b,i,k] = Σ_{j∈group k} (Wq[i,j] − z[i,k]) · x[b,j].

    The per-group partial products of the *integer* matrix with the
    activations; the PEQA forward is y = Σ_k s[:,k] ⊙ u[:,:,k] and the
    scale gradient is ds[i,k] = Σ_b dy[b,i]·u[b,i,k] (see peqa_grad_ref).
    x: (B, m) -> u: (B, n, G)
    """
    n, m = wq.shape
    G = z.shape[1]
    g = m // G
    wg = _group(wq, g) - z[:, :, None]          # (n, G, g)
    xg = x.reshape(x.shape[0], G, g)            # (B, G, g)
    return jnp.einsum("bkj,nkj->bnk", xg, wg)   # (B, n, G)


def peqa_grad_ref(dy, x, wq, s, z):
    """Reference gradients for the PEQA linear (paper Eq. 2).

    y[b,i] = Σ_k s[i,k] · u[b,i,k]   with u from group_partials_ref.

      ds[i,k] = Σ_b dy[b,i] · u[b,i,k]
      dz[i,k] = −s[i,k] · Σ_b dy[b,i] · (Σ_{j∈k} x[b,j])
      dx      = dy @ Ŵ

    Returns (ds, dz, dx).
    """
    u = group_partials_ref(x, wq, z)                     # (B, n, G)
    ds = jnp.einsum("bi,bik->ik", dy, u)                 # (n, G)
    G = z.shape[1]
    g = x.shape[1] // G
    xsum = x.reshape(x.shape[0], G, g).sum(axis=2)       # (B, G)
    dz = -s * jnp.einsum("bi,bk->ik", dy, xsum)          # (n, G)
    dx = qmatmul_t_ref(dy, wq, s, z)                     # (B, m)
    return ds, dz, dx
