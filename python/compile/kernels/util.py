"""Shared helpers for the Pallas kernels: block-size selection and the
VMEM/MXU roofline model used to pick TPU tile shapes (DESIGN.md
§Hardware-Adaptation).

All kernels in this package run under ``interpret=True`` — the CPU PJRT
client cannot execute Mosaic custom-calls — so kernel *structure* (tiling,
traffic) is what we optimize; wallclock is estimated from the model below.
"""

from __future__ import annotations

from dataclasses import dataclass

# TPU-v4-ish budget constants used by the roofline estimate. These are
# deliberately round numbers: the estimate feeds a *ratio* (achieved vs
# roofline), not absolute TFLOPs.
VMEM_BYTES = 16 * 2**20          # per-core VMEM
HBM_GBPS = 1200.0                # HBM bandwidth
MXU_TFLOPS = 137.0               # bf16 peak


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``target``.

    Our model dims are powers of two (or small multiples), so this finds
    the natural tile; worst case it degrades to 1 which is still correct.
    """
    if dim <= target:
        return dim
    for b in range(target, 0, -1):
        if dim % b == 0:
            return b
    return 1


@dataclass
class TileEstimate:
    """Roofline estimate for one qmatmul tile configuration."""

    vmem_bytes: int        # live bytes per grid step
    hbm_bytes: int         # total HBM traffic for the whole product
    flops: int             # total MACs * 2
    mxu_util: float        # flops-limited utilization estimate (0..1)
    mem_bound_s: float     # time if purely bandwidth-bound
    flop_bound_s: float    # time if purely MXU-bound

    @property
    def est_s(self) -> float:
        return max(self.mem_bound_s, self.flop_bound_s)


def qmatmul_tile_estimate(
    batch: int, n: int, m: int, bits: int, bb: int, nb: int, gb: int
) -> TileEstimate:
    """VMEM footprint + traffic model for qmatmul with tiles (bb, nb, gb).

    Weight codes stream HBM→VMEM at ``bits``-bit density (packed in HBM);
    they are unpacked to int8 and dequantized to f32 in VMEM, so the VMEM
    cost is the *unpacked* tile while the HBM cost is the packed one —
    exactly the memory-traffic trade the paper's GPU kernels (OPTQ /
    LUT-GEMM) make with global memory vs registers.
    """
    # Live per step: x tile (f32), packed+unpacked weight tile, scale/zp
    # column, f32 dequant tile, output accumulator. Double-buffered streams
    # count twice (Pallas pipelining).
    w_packed = nb * gb * bits // 8
    w_unpacked = nb * gb * 4  # dequantized f32 staged for the MXU
    vmem = 2 * (bb * gb * 4 + w_packed) + w_unpacked + 2 * nb * 4 + bb * nb * 4
    hbm = n * m * bits // 8 + batch * m * 4 + batch * n * 4
    flops = 2 * batch * n * m
    mem_s = hbm / (HBM_GBPS * 1e9)
    flop_s = flops / (MXU_TFLOPS * 1e12)
    # MXU prefers ≥128×128 operands; penalize thin tiles linearly.
    util = min(1.0, bb / 128.0) * min(1.0, nb / 128.0)
    return TileEstimate(vmem, hbm, flops, util, mem_s, flop_s)
