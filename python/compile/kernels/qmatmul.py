"""Pallas fused dequantize-and-matmul — the deployment hot-spot.

The paper's inference speedup comes from GPU weight-only-quant GEMV kernels
(OPTQ's kernels, LUT-GEMM) that keep weights sub-4-bit in global memory and
dequantize in registers. TPU adaptation (DESIGN.md §Hardware-Adaptation):

  • HBM→VMEM streams the *quantized* weight tile (b-bit density), cutting
    the memory-bound decode path's traffic by 16/b — the same trade the GPU
    kernel makes with DRAM→register loads.
  • Dequant  Ŵ = s·(Wq − z)  runs on the VPU inside VMEM, then the MXU
    consumes the f32/bf16 tile — the analog of in-register dequant feeding
    tensor-core WMMA.
  • The GPU one-threadblock-per-output-tile schedule becomes
    grid = (B/bb, n/nb, G) with the group axis as the sequential reduction
    dimension; Pallas double-buffers the weight stream across the G axis.

Two kernels live here: ``qmatmul`` (y = x @ Ŵᵀ, the forward / decode GEMV)
and ``qmatmul_t`` (dx = dy @ Ŵ, the activation gradient in the PEQA VJP).
Both are checked against kernels/ref.py by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import pick_block


def _qmm_kernel(x_ref, wq_ref, s_ref, z_ref, y_ref):
    """One (bb × nb) output tile, accumulating over the group axis k."""
    k = pl.program_id(2)
    x = x_ref[...]                                    # (bb, g)
    w = (wq_ref[...] - z_ref[...]) * s_ref[...]       # dequant in VMEM (nb, g)
    part = jnp.dot(x, w.T)                            # MXU: (bb, nb)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = part

    @pl.when(k != 0)
    def _acc():
        y_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_b", "block_n"))
def qmatmul(x, wq, s, z, block_b: int = 128, block_n: int = 128):
    """y = x @ (s·(Wq − z))ᵀ.   x: (B, m), wq: (n, m), s/z: (n, G) → (B, n)."""
    B, m = x.shape
    n, m2 = wq.shape
    assert m == m2, (x.shape, wq.shape)
    G = s.shape[1]
    g = m // G
    bb = pick_block(B, block_b)
    nb = pick_block(n, block_n)
    return pl.pallas_call(
        _qmm_kernel,
        grid=(B // bb, n // nb, G),
        in_specs=[
            pl.BlockSpec((bb, g), lambda i, j, k: (i, k)),
            pl.BlockSpec((nb, g), lambda i, j, k: (j, k)),
            pl.BlockSpec((nb, 1), lambda i, j, k: (j, k)),
            pl.BlockSpec((nb, 1), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bb, nb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, n), x.dtype),
        interpret=True,
    )(x, wq, s, z)


def _qmm_t_kernel(dy_ref, wq_ref, s_ref, z_ref, dx_ref):
    """One (bb × g) dx tile, accumulating over row tiles r."""
    r = pl.program_id(2)
    dy = dy_ref[...]                                  # (bb, nr)
    w = (wq_ref[...] - z_ref[...]) * s_ref[...]       # (nr, g)
    part = jnp.dot(dy, w)                             # (bb, g)

    @pl.when(r == 0)
    def _init():
        dx_ref[...] = part

    @pl.when(r != 0)
    def _acc():
        dx_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_b", "block_n"))
def qmatmul_t(dy, wq, s, z, block_b: int = 128, block_n: int = 128):
    """dx = dy @ (s·(Wq − z)).   dy: (B, n) → dx: (B, m)."""
    B, n = dy.shape
    n2, m = wq.shape
    assert n == n2
    G = s.shape[1]
    g = m // G
    bb = pick_block(B, block_b)
    nr = pick_block(n, block_n)
    return pl.pallas_call(
        _qmm_t_kernel,
        grid=(B // bb, G, n // nr),
        in_specs=[
            pl.BlockSpec((bb, nr), lambda i, k, r: (i, r)),
            pl.BlockSpec((nr, g), lambda i, k, r: (r, k)),
            pl.BlockSpec((nr, 1), lambda i, k, r: (r, k)),
            pl.BlockSpec((nr, 1), lambda i, k, r: (r, k)),
        ],
        out_specs=pl.BlockSpec((bb, g), lambda i, k, r: (i, k)),
        out_shape=jax.ShapeDtypeStruct((B, m), dy.dtype),
        interpret=True,
    )(dy, wq, s, z)
