"""Pallas kernel for the PEQA backward pass — the *training* hot-spot.

PEQA's gradient structure is what makes scale-only fine-tuning cheap
(paper Eq. 2). With  y[b,i] = Σ_k s[i,k] · u[b,i,k]  and
u[b,i,k] = Σ_{j∈group k} (Wq[i,j] − z[i,k]) x[b,j]:

    ds[i,k] = Σ_b dy[b,i] · u[b,i,k]              (scale gradient)
    dz[i,k] = −s[i,k] · Σ_b dy[b,i] · xsum[b,k]   (zero-point gradient)

i.e. the weight-shaped gradient dŴ = dyᵀx is *never materialized*: the
scale gradient reuses the same integer-matrix product as the forward. The
kernel fuses the group partial product u with the dy reduction so u is
consumed tile-by-tile in VMEM and never written to HBM.

Grid = (n/nb, G); each program computes one (nb × 1) column of ds and dz.
The B (tokens) axis is kept whole per tile: in training B = batch·seq is
the MXU-friendly long dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import pick_block


def _grad_kernel(dy_ref, x_ref, wq_ref, s_ref, z_ref, ds_ref, dz_ref):
    dy = dy_ref[...]                                  # (B, nb)
    x = x_ref[...]                                    # (B, g)
    wint = wq_ref[...] - z_ref[...]                   # (nb, g) integer part
    u = jnp.dot(x, wint.T)                            # (B, nb) group partials
    ds_ref[...] = jnp.sum(dy * u, axis=0, keepdims=True).T          # (nb, 1)
    xsum = jnp.sum(x, axis=1, keepdims=True)                        # (B, 1)
    dz_ref[...] = -s_ref[...] * jnp.dot(dy.T, xsum)                 # (nb, 1)


@functools.partial(jax.jit, static_argnames=("block_n",))
def peqa_grad(dy, x, wq, s, z, block_n: int = 128):
    """Fused (ds, dz) for the PEQA linear.

    dy: (B, n), x: (B, m), wq: (n, m), s/z: (n, G)  →  ds, dz: (n, G).
    dx is produced separately by qmatmul_t (it is a plain dequant-matmul).
    """
    B, n = dy.shape
    _, m = x.shape
    G = s.shape[1]
    g = m // G
    nb = pick_block(n, block_n)
    ds, dz = pl.pallas_call(
        _grad_kernel,
        grid=(n // nb, G),
        in_specs=[
            pl.BlockSpec((B, nb), lambda i, k: (0, i)),
            pl.BlockSpec((B, g), lambda i, k: (0, k)),
            pl.BlockSpec((nb, g), lambda i, k: (i, k)),
            pl.BlockSpec((nb, 1), lambda i, k: (i, k)),
            pl.BlockSpec((nb, 1), lambda i, k: (i, k)),
        ],
        out_specs=[
            pl.BlockSpec((nb, 1), lambda i, k: (i, k)),
            pl.BlockSpec((nb, 1), lambda i, k: (i, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, G), dy.dtype),
            jax.ShapeDtypeStruct((n, G), dy.dtype),
        ],
        interpret=True,
    )(dy, x, wq, s, z)
    return ds, dz
