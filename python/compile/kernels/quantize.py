"""Pallas RTN quantization kernel (paper Eq. 1 initialization).

Grid is (row-tiles, groups): each program owns an (nb × g) slab of W —
one quantization group for nb output channels — computes the asymmetric
min/max scale and zero-point, and emits integer codes plus the (nb × 1)
scale/zero-point columns.

On TPU this is a single HBM→VMEM sweep of W (read once, write codes once);
min/max/round are VPU work, there is no MXU involvement. The kernel exists
so that quantization of a checkpoint is itself an AOT artifact the rust
side can execute (``peqa quantize``) without Python.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS
from .util import pick_block


def _rtn_kernel(w_ref, wq_ref, s_ref, z_ref, *, qmax: float):
    w = w_ref[...]                                   # (nb, g)
    # Zero forced into range — see kernels/ref.py for the rationale.
    wmin = jnp.minimum(jnp.min(w, axis=1, keepdims=True), 0.0)
    wmax = jnp.maximum(jnp.max(w, axis=1, keepdims=True), 0.0)
    s = jnp.maximum((wmax - wmin) / qmax, EPS)       # (nb, 1)
    z = jnp.clip(jnp.round(-wmin / s), 0.0, qmax)    # (nb, 1)
    wq_ref[...] = jnp.clip(jnp.round(w / s) + z, 0.0, qmax)
    s_ref[...] = s
    z_ref[...] = z


@functools.partial(jax.jit, static_argnames=("bits", "group", "row_block"))
def quantize_rtn(w, bits: int, group: int | None = None, row_block: int = 256):
    """Quantize (n, m) weights; returns (codes (n,m), s (n,G), z (n,G)).

    Codes are returned as float32 holding exact integers in [0, 2^bits−1]
    so that downstream HLO stays in one dtype; the rust side packs them to
    real sub-4-bit storage (rust/src/quant/pack.rs).
    """
    n, m = w.shape
    group = m if group is None else group
    assert m % group == 0
    ngroups = m // group
    nb = pick_block(n, row_block)
    kernel = functools.partial(_rtn_kernel, qmax=float(2**bits - 1))
    wq, s, z = pl.pallas_call(
        kernel,
        grid=(n // nb, ngroups),
        in_specs=[pl.BlockSpec((nb, group), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((nb, group), lambda i, j: (i, j)),
            pl.BlockSpec((nb, 1), lambda i, j: (i, j)),
            pl.BlockSpec((nb, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), w.dtype),
            jax.ShapeDtypeStruct((n, ngroups), w.dtype),
            jax.ShapeDtypeStruct((n, ngroups), w.dtype),
        ],
        interpret=True,
    )(w)
    return wq, s, z
