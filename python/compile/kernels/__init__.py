"""L1 — Pallas kernels for PEQA's compute hot-spots.

``quantize.quantize_rtn``  RTN asymmetric quantization (Eq. 1 init)
``qmatmul.qmatmul``        fused dequantize-and-matmul  y = x @ (s·(Wq−z))ᵀ
``qmatmul.qmatmul_t``      transposed product           dx = dy @ Ŵ
``peqa_grad.peqa_grad``    fused scale / zero-point gradients (Eq. 2 bwd)
``ref``                    pure-jnp oracles for all of the above

All kernels run interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); see DESIGN.md §Hardware-Adaptation for the TPU tiling story.
"""

from .peqa_grad import peqa_grad
from .qmatmul import qmatmul, qmatmul_t
from .quantize import quantize_rtn

__all__ = ["quantize_rtn", "qmatmul", "qmatmul_t", "peqa_grad"]
