"""AOT lowering: jax functions → HLO *text* artifacts + JSON metadata.

Interchange format is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each manifest entry becomes:

    artifacts/<name>.hlo.txt    the computation
    artifacts/<name>.meta.json  io signature + ordered param table + config

Usage:  python -m compile.aot [--out-dir ../artifacts] [--only REGEX]
                              [--force] [--list]

Incremental: an artifact is re-lowered only if its files are missing or
older than any source file in compile/ (or --force).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, train
from .configs import DISPLAY, SIZES, ArtifactSpec
from .kernels import qmatmul, quantize_rtn
from .model import MethodConfig


def to_hlo_text(fn, arg_specs) -> str:
    # keep_unused=True: the rust runtime feeds every input in the meta
    # signature; without it XLA prunes unused params (e.g. lm_head in the
    # hessian artifact) and the buffer counts no longer line up.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _params_meta(specs):
    return [
        {"name": p.name, "shape": list(p.shape), "trainable": p.trainable,
         "init": p.init}
        for p in specs
    ]


def _mcfg_meta(m: MethodConfig | None):
    if m is None:
        return None
    return {
        "kind": m.kind, "bits": m.bits, "group": m.group, "tag": m.tag(),
        "train_scales": m.train_scales, "train_zeros": m.train_zeros,
        "rank": m.rank, "lora_targets": list(m.lora_targets),
        "lora_alpha": m.lora_alpha,
    }


def build(art: ArtifactSpec):
    """-> (fn, arg_specs, meta dict) for one manifest entry."""
    meta = {"name": art.name, "kind": art.kind, "batch": art.batch}
    if art.size:
        cfg = SIZES[art.size]
        meta["size"] = art.size
        meta["display"] = DISPLAY[art.size]
        meta["model"] = {
            "family": cfg.family, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len, "n_params": cfg.n_params(),
        }
    meta["method"] = _mcfg_meta(art.method)
    B = art.batch

    if art.kind == "train":
        T = cfg.seq_len
        fn, tr, fz = train.make_train_step(cfg, art.method)
        args = [
            _spec((B, T), jnp.int32), _spec((B, T - 1)), _spec(()), _spec(()),
        ]
        args += [_spec(p.shape) for p in tr]          # trainable
        args += [_spec(p.shape) for p in fz]          # frozen
        args += [_spec(p.shape) for p in tr] * 2      # m, v
        meta["inputs"] = (
            [_io("tokens", (B, T), "i32"), _io("mask", (B, T - 1)),
             _io("lr", ()), _io("step", ())]
            + [_io(p.name, p.shape) for p in tr]
            + [_io(p.name, p.shape) for p in fz]
            + [_io(f"m.{p.name}", p.shape) for p in tr]
            + [_io(f"v.{p.name}", p.shape) for p in tr]
        )
        meta["outputs"] = (
            [_io("loss", ())]
            + [_io(p.name, p.shape) for p in tr]
            + [_io(f"m.{p.name}", p.shape) for p in tr]
            + [_io(f"v.{p.name}", p.shape) for p in tr]
        )
        meta["params_trainable"] = _params_meta(tr)
        meta["params_frozen"] = _params_meta(fz)
        return fn, args, meta

    if art.kind == "eval":
        T = cfg.seq_len
        fn, table = train.make_eval(cfg)
        args = [_spec((B, T), jnp.int32), _spec((B, T - 1))]
        args += [_spec(p.shape) for p in table]
        meta["inputs"] = [
            _io("tokens", (B, T), "i32"), _io("mask", (B, T - 1)),
        ] + [_io(p.name, p.shape) for p in table]
        meta["outputs"] = [_io("sum_nll", ()), _io("n_tokens", ())]
        meta["params"] = _params_meta(table)
        return fn, args, meta

    if art.kind in ("logits", "logits_q"):
        T = cfg.seq_len
        if art.kind == "logits":
            fn, table = train.make_logits(cfg)
        else:
            fn, table = train.make_logits_q(cfg, art.method)
        args = [_spec((B, T), jnp.int32)] + [_spec(p.shape) for p in table]
        meta["inputs"] = [_io("tokens", (B, T), "i32")] + [
            _io(p.name, p.shape) for p in table
        ]
        meta["outputs"] = [_io("logits", (B, T, cfg.vocab))]
        meta["params"] = _params_meta(table)
        return fn, args, meta

    if art.kind == "hess":
        T = cfg.seq_len
        fn, table = train.make_hessians(cfg)
        names = train.hessian_names(cfg)
        d, f = cfg.d_model, cfg.d_ff
        fam_shape = {"qkv": (d, d), "o": (d, d), "gateup": (d, d),
                     "fc1": (d, d), "down": (f, f), "fc2": (f, f)}
        args = [_spec((B, T), jnp.int32)] + [_spec(p.shape) for p in table]
        meta["inputs"] = [_io("tokens", (B, T), "i32")] + [
            _io(p.name, p.shape) for p in table
        ]
        meta["outputs"] = [
            _io(n, fam_shape[n.rsplit(".", 1)[1]]) for n in names
        ]
        meta["params"] = _params_meta(table)
        return fn, args, meta

    if art.kind == "prep":
        fn, fp_table, out_table = train.make_prep(cfg, art.method)
        args = [_spec(p.shape) for p in fp_table]
        meta["inputs"] = [_io(p.name, p.shape) for p in fp_table]
        meta["outputs"] = [_io(p.name, p.shape) for p in out_table]
        meta["params"] = _params_meta(out_table)
        return fn, args, meta

    if art.kind == "kernel":
        ex = art.extra
        n, m, bits, group = ex["n"], ex["m"], ex["bits"], ex["group"]
        if ex["op"] == "qmatmul":
            b = ex["b"]
            G = m // group

            def fn(x, wq, s, z):
                return (qmatmul(x, wq, s, z),)

            args = [_spec((b, m)), _spec((n, m)), _spec((n, G)), _spec((n, G))]
            meta["inputs"] = [
                _io("x", (b, m)), _io("wq", (n, m)), _io("s", (n, G)),
                _io("z", (n, G)),
            ]
            meta["outputs"] = [_io("y", (b, n))]
        elif ex["op"] == "rtn":
            G = m // group

            def fn(w):
                return tuple(quantize_rtn(w, bits, group))

            args = [_spec((n, m))]
            meta["inputs"] = [_io("w", (n, m))]
            meta["outputs"] = [
                _io("wq", (n, m)), _io("s", (n, G)), _io("z", (n, G)),
            ]
        else:
            raise ValueError(ex)
        meta["extra"] = ex
        return fn, args, meta

    raise ValueError(f"unknown artifact kind {art.kind}")


def newest_source_mtime() -> float:
    src_dir = os.path.dirname(os.path.abspath(__file__))
    mt = 0.0
    for root, _, files in os.walk(src_dir):
        for f in files:
            if f.endswith(".py"):
                mt = max(mt, os.path.getmtime(os.path.join(root, f)))
    return mt


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="regex over artifact names")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    arts = configs.manifest()
    if args.only:
        rx = re.compile(args.only)
        arts = [a for a in arts if rx.search(a.name)]
    if args.list:
        for a in arts:
            print(f"{a.name:40s} {a.kind}")
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    src_mtime = newest_source_mtime()
    done = skipped = 0
    t0 = time.time()
    for a in arts:
        hlo_path = os.path.join(args.out_dir, f"{a.name}.hlo.txt")
        meta_path = os.path.join(args.out_dir, f"{a.name}.meta.json")
        if (
            not args.force
            and os.path.exists(hlo_path)
            and os.path.exists(meta_path)
            and os.path.getmtime(hlo_path) >= src_mtime
        ):
            skipped += 1
            continue
        t = time.time()
        fn, arg_specs, meta = build(a)
        text = to_hlo_text(fn, arg_specs)
        with open(hlo_path, "w") as f:
            f.write(text)
        with open(meta_path, "w") as f:
            json.dump(meta, f, indent=1)
        done += 1
        print(f"[aot] {a.name:44s} {len(text)/1e6:6.2f} MB  {time.time()-t:5.1f}s",
              flush=True)
    print(f"[aot] lowered {done}, up-to-date {skipped}, total {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
