"""PEQA linear layer (paper Eq. 2) and the other quantized-linear methods.

The core contribution: a fully-connected layer whose weight is a *frozen*
integer matrix Wq with trainable quantization scales s (and optionally
zero-points z):

    y = x @ (s · (Wq − z))ᵀ

``peqa_linear`` wires the L1 Pallas kernels into jax autodiff with a
``custom_vjp`` so that

  • the integer matrix receives an exact-zero cotangent (it is frozen, and
    the weight-shaped gradient dŴ = dyᵀx is never materialized),
  • ds / dz come from the fused ``peqa_grad`` kernel,
  • dx comes from the transposed dequant-matmul ``qmatmul_t``.

Also here: the straight-through fake-quantizer used by the QAT baseline
and the binary-coding (AlphaTuning) representation used by Table 15.

Set env PEQA_USE_PALLAS=0 to route the forward/backward through the
pure-jnp oracles instead of the Pallas kernels (the two are tested equal;
the ref path lowers to marginally leaner HLO on CPU — see DESIGN §Perf).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .kernels import peqa_grad, qmatmul, qmatmul_t, quantize_rtn
from .kernels import ref

USE_PALLAS = os.environ.get("PEQA_USE_PALLAS", "1") != "0"

# Block targets for model-internal kernel calls: at reproduction scale
# (d ≤ 320, B·T ≤ 1024) these give grid≈1 so interpret-mode overhead is
# nil; at TPU scale they are the VMEM-budget tiles from DESIGN
# §Hardware-Adaptation. Multi-block grids are exercised by pytest.
BLOCK_B = 512
BLOCK_N = 512


@jax.custom_vjp
def _peqa_mm(x2d, wq, s, z):
    if USE_PALLAS:
        return qmatmul(x2d, wq, s, z, block_b=BLOCK_B, block_n=BLOCK_N)
    return ref.qmatmul_ref(x2d, wq, s, z)


def _peqa_mm_fwd(x2d, wq, s, z):
    return _peqa_mm(x2d, wq, s, z), (x2d, wq, s, z)


def _peqa_mm_bwd(res, dy):
    x2d, wq, s, z = res
    if USE_PALLAS:
        ds, dz = peqa_grad(dy, x2d, wq, s, z, block_n=BLOCK_N)
        dx = qmatmul_t(dy, wq, s, z, block_b=BLOCK_B, block_n=BLOCK_N)
    else:
        ds, dz, dx = ref.peqa_grad_ref(dy, x2d, wq, s, z)
    # Frozen integer matrix: exact-zero cotangent, never dense dyᵀx.
    return dx, jnp.zeros_like(wq), ds, dz


_peqa_mm.defvjp(_peqa_mm_fwd, _peqa_mm_bwd)


def peqa_linear(x, wq, s, z):
    """y = x @ (s·(Wq − z))ᵀ for x of shape (..., m); grads reach s and z only."""
    lead = x.shape[:-1]
    m = x.shape[-1]
    y = _peqa_mm(x.reshape(-1, m), wq, s, z)
    return y.reshape(*lead, wq.shape[0])


# ---------------------------------------------------------------------------
# QAT baseline: straight-through fake-quantization (trains ALL weights).
# ---------------------------------------------------------------------------


def fake_quant_ste(w, bits: int, group: int | None = None):
    """RTN fake-quant with a straight-through estimator.

    Forward sees the dequantized weights; backward passes gradients to w
    unchanged (the rounding is treated as identity), which is the simple
    QAT recipe the paper uses as its upper-bound baseline (Table 2).
    """
    wq, s, z = ref.quantize_rtn_ref(w, bits, group)
    what = ref.dequant_ref(wq, s, z)
    return w + jax.lax.stop_gradient(what - w)


def qat_linear(x, w, bits: int, group: int | None = None):
    return x @ fake_quant_ste(w, bits, group).T


# ---------------------------------------------------------------------------
# AlphaTuning baseline (Table 15): binary-coding quantization W ≈ Σ_k α_k·B_k
# with per-channel α ∈ R^{n×b}, codes B_k ∈ {−1,+1}^{n×m}; only α_1 trains.
# ---------------------------------------------------------------------------


def bcq_quantize(w, bits: int, iters: int = 3):
    """Greedy binary-coding quantization + alternating refinement.

    Returns (alpha (n, bits), codes (n, m, bits) in {−1,+1}).
    Greedy: B_k = sign(R), α_k = ⟨R, B_k⟩/m per channel on the residual R;
    then a few alternating-least-squares sweeps re-fit each α_k.
    """
    n, m = w.shape
    r = w
    alphas, codes = [], []
    for _ in range(bits):
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.sum(r * b, axis=1) / m          # LS-optimal per-channel α
        alphas.append(a)
        codes.append(b)
        r = r - a[:, None] * b
    alpha = jnp.stack(alphas, axis=1)           # (n, bits)
    code = jnp.stack(codes, axis=2)             # (n, m, bits)
    for _ in range(iters):
        # Coordinate-descent refit of each α_k (closed form; NO
        # jnp.linalg.solve — LAPACK custom-calls use the typed-FFI API
        # which xla_extension 0.5.1 cannot compile).
        recon = jnp.einsum("nk,nmk->nm", alpha, code)
        for k in range(bits):
            rk = w - recon + alpha[:, k : k + 1] * code[:, :, k]
            ak = jnp.sum(rk * code[:, :, k], axis=1) / m
            recon = recon + (ak - alpha[:, k])[:, None] * code[:, :, k]
            alpha = alpha.at[:, k].set(ak)
        # Re-fit codes greedily against the new alphas.
        r = w
        cs = []
        for k in range(bits):
            b = jnp.where(r >= 0, 1.0, -1.0)
            cs.append(b)
            r = r - alpha[:, k : k + 1] * b
        code = jnp.stack(cs, axis=2)
    return alpha, code


def bcq_dequant(alpha, code):
    """Ŵ = Σ_k α_k ⊙ B_k.  alpha: (n, b), code: (n, m, b) → (n, m)."""
    return jnp.einsum("nk,nmk->nm", alpha, code)


def alphatuning_linear(x, alpha, code):
    return x @ bcq_dequant(alpha, code).T
