"""Model families and the AOT artifact manifest.

§Substitutions (DESIGN.md): the paper's 2.7B–65B model zoo is scaled to a
family that pretrains + fine-tunes on a single CPU core while spanning a
~30× parameter range, so every scaling trend (Tables 3/4, Fig. 2b) can be
measured. Names carry the analogy explicitly.

The MANIFEST enumerates every artifact `make artifacts` emits; each entry
becomes artifacts/<name>.hlo.txt + artifacts/<name>.meta.json. Benches and
the rust CLI refer to artifacts by these names.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .model import LORA_QKVO16, LORA_QV4, MethodConfig, ModelConfig

TRAIN_BATCH = 8
EVAL_BATCH = 8
SEQ_LEN = 64

# LLaMA-analog family (RMSNorm + RoPE + SwiGLU). display = paper model.
LLAMA_SIZES: dict[str, ModelConfig] = {
    "n1": ModelConfig("n1", "llama", 512, 64, 2, 4, 192, SEQ_LEN),
    "n2": ModelConfig("n2", "llama", 512, 96, 2, 6, 256, SEQ_LEN),
    "n3": ModelConfig("n3", "llama", 512, 128, 3, 8, 384, SEQ_LEN),
    "n4": ModelConfig("n4", "llama", 512, 192, 3, 8, 512, SEQ_LEN),
    "n5": ModelConfig("n5", "llama", 512, 256, 4, 8, 704, SEQ_LEN),
    "n6": ModelConfig("n6", "llama", 512, 320, 4, 8, 832, SEQ_LEN),
}
DISPLAY = {
    "n1": "GPT-Neo-2.7B-sim",
    "n2": "GPT-J-6B-sim",
    "n3": "LLaMA-7B-sim",
    "n4": "LLaMA-13B-sim",
    "n5": "LLaMA-30B-sim",
    "n6": "LLaMA-65B-sim",
    "o1": "OPT-1.3B-sim",
    "o2": "OPT-2.7B-sim",
    "o3": "OPT-6.7B-sim",
    "o4": "OPT-13B-sim",
    "o5": "OPT-30B-sim",
    "o6": "OPT-66B-sim",
}

# OPT-analog family (LayerNorm + learned positions + GELU, d_ff = 4d).
OPT_SIZES: dict[str, ModelConfig] = {
    "o1": ModelConfig("o1", "opt", 512, 48, 2, 3, 192, SEQ_LEN),
    "o2": ModelConfig("o2", "opt", 512, 64, 2, 4, 256, SEQ_LEN),
    "o3": ModelConfig("o3", "opt", 512, 96, 2, 6, 384, SEQ_LEN),
    "o4": ModelConfig("o4", "opt", 512, 128, 3, 8, 512, SEQ_LEN),
    "o5": ModelConfig("o5", "opt", 512, 160, 3, 8, 640, SEQ_LEN),
    "o6": ModelConfig("o6", "opt", 512, 192, 4, 8, 768, SEQ_LEN),
}

SIZES: dict[str, ModelConfig] = {**LLAMA_SIZES, **OPT_SIZES}

# The paper's group-size sweep (Table 5), scaled: channel-wise + g∈{64,32,16}.
GROUP_SWEEP = [64, 32, 16]


def peqa(bits: int, group: int | None = None, **kw) -> MethodConfig:
    return MethodConfig(kind="peqa", bits=bits, group=group, **kw)


@dataclass(frozen=True)
class ArtifactSpec:
    """One AOT artifact: a jax function + shapes, lowered to HLO text."""

    name: str
    kind: str                   # train | eval | logits | logits_q | hess | prep | kernel
    size: str | None = None     # key into SIZES
    method: MethodConfig | None = None
    batch: int = TRAIN_BATCH
    extra: dict = field(default_factory=dict)


def manifest() -> list[ArtifactSpec]:
    """Every artifact the reproduction needs (DESIGN.md experiment index)."""
    arts: list[ArtifactSpec] = []

    def add(name, kind, size=None, method=None, **kw):
        arts.append(ArtifactSpec(name, kind, size, method, **kw))

    # -- Shared per-size artifacts (fp layout; methods dequantize into it).
    for s in SIZES:
        add(f"{s}_eval", "eval", s, batch=EVAL_BATCH)
        add(f"{s}_train_full", "train", s, MethodConfig(kind="full"))
        add(f"{s}_train_lora_qv4", "train", s, LORA_QV4)
        add(f"{s}_train_peqa_b4_gc", "train", s, peqa(4))
        add(f"{s}_prep_peqa_b4_gc", "prep", s, peqa(4))

    llama = list(LLAMA_SIZES)
    for s in llama:
        # 3-bit PEQA (Tables 2/3 sub-4-bit rows) — llama family only.
        add(f"{s}_train_peqa_b3_gc", "train", s, peqa(3))
        add(f"{s}_prep_peqa_b3_gc", "prep", s, peqa(3))
        # Batch logits for multiple-choice scoring (Tables 6/7) + serving.
        add(f"{s}_logits_b8", "logits", s, batch=8)
        # Hessian calibration (OPTQ baseline of Tables 2/3, Fig. 3).
        add(f"{s}_hess", "hess", s, batch=EVAL_BATCH)
        add(f"{s}_train_lora_qkvo16", "train", s, LORA_QKVO16)  # Tables 6/11
    for s in ("n3", "n4"):
        add(f"{s}_logits_b1", "logits", s, batch=1)  # single-stream decode

    # -- QAT upper-bound baseline (Table 2: four smallest llama analogs).
    for s in llama[:4]:
        for bits in (3, 4):
            add(f"{s}_train_qat_b{bits}", "train", s,
                MethodConfig(kind="qat", bits=bits))

    # -- Group-size sweep (Table 5) on the 7B/13B analogs.
    for s in ("n3", "n4"):
        for bits in (3, 4):
            for g in GROUP_SWEEP:
                add(f"{s}_train_peqa_b{bits}_g{g}", "train", s, peqa(bits, g))
                add(f"{s}_prep_peqa_b{bits}_g{g}", "prep", s, peqa(bits, g))

    # -- Zero-point ablation (Table 17) on the 7B/13B analogs, 4-bit.
    for s in ("n3", "n4"):
        add(f"{s}_train_peqa_zp_b4_gc", "train", s,
            peqa(4, train_scales=False, train_zeros=True))
        add(f"{s}_train_peqa_szp_b4_gc", "train", s,
            peqa(4, train_scales=True, train_zeros=True))

    # -- AlphaTuning baseline (Table 15) on the 1.3B-analog sizes.
    for s in ("n1", "n2"):
        for bits in (3, 4):
            add(f"{s}_train_alpha_b{bits}", "train", s,
                MethodConfig(kind="alpha", bits=bits))
            add(f"{s}_prep_alpha_b{bits}", "prep", s,
                MethodConfig(kind="alpha", bits=bits))

    # -- Quantized-layout serving forward (Pallas qmatmul on the hot path).
    for s in ("n3", "n4"):
        add(f"{s}_logits_q_b4_gc_b1", "logits_q", s, peqa(4), batch=1)
        add(f"{s}_logits_q_b4_gc_b8", "logits_q", s, peqa(4), batch=8)

    # -- Standalone kernel artifacts: rust cross-checks + micro-bench.
    add("kernel_qmatmul_256", "kernel", extra={"op": "qmatmul", "n": 256, "m": 256, "b": 8, "bits": 4, "group": 64})
    add("kernel_rtn_256", "kernel", extra={"op": "rtn", "n": 256, "m": 256, "bits": 4, "group": 64})
    return arts
