//! Multi-task serving (Table 1's deployment story, live).
//!
//! One frozen 4-bit integer model, two task adapters (wikitext-sim /
//! ptb-sim scale vectors). The threaded server (engine thread + channel
//! frontend, vLLM-router style) receives an interleaved request stream
//! from 4 concurrent client threads; the dynamic batcher groups
//! same-task requests and scale-swaps between tasks. Reports throughput,
//! latency percentiles and the measured adapter-swap cost.
//!
//! Run: cargo run --release --example multitask_server [-- --requests 24]

use peqa::cli::Args;
use peqa::coordinator::server::{Server, ServerConfig};
use peqa::pipeline::{self, Ctx};
use peqa::tokenizer::{Tokenizer, EOS};

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    let size = args.get("size", "n3");
    let n_req = args.get_usize("requests", 24)?;
    args.finish()?;

    // ---- Offline: build base + adapters (cached across runs). ----
    let (artifacts_dir, base_path, adapters_dir);
    {
        let ctx = Ctx::new()?;
        let base = pipeline::ensure_base(&ctx, &size, pipeline::pretrain_steps())?;
        let mut store = peqa::coordinator::AdapterStore::new();
        let mut base_q = None;
        for task in ["wikitext", "ptb"] {
            let ck = pipeline::finetune_cached(&ctx, &size, "peqa_b4_gc", task, 100)?;
            if base_q.is_none() {
                base_q = Some(ck.clone());
            }
            store.insert(task, ck.extract_adapter(false));
        }
        adapters_dir = ctx.paths.checkpoints.join("adapters");
        std::fs::create_dir_all(&adapters_dir)?;
        store.save_all(&adapters_dir)?;
        base_path = ctx.paths.checkpoints.join(format!("{size}_serving_base.peqa"));
        base_q.unwrap().save(&base_path)?;
        artifacts_dir = ctx.paths.artifacts.clone();
        let _ = base;
    } // Ctx (and its PJRT client) dropped before the engine thread starts.

    // ---- Online: threaded engine + concurrent clients. ----
    let server = Server::spawn(ServerConfig {
        artifacts_dir,
        artifact_name: format!("{size}_logits_q_b4_gc_b8"),
        base_path,
        adapters_dir,
        scale_swap: true,
        max_batch: 8,
    })?;
    let tok = Tokenizer::byte_level(512);
    let prompts =
        ["the empire of", "shares of acme", "the battle of", "analysts expect", "the kingdom of"];
    let mut clients = Vec::new();
    let t0 = std::time::Instant::now();
    for c in 0..4usize {
        let handle = server.handle();
        let ids: Vec<Vec<u32>> = prompts.iter().map(|p| tok.encode(p)).collect();
        clients.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut lat = Vec::new();
            for i in 0..n_req / 4 {
                let task = if (c + i) % 2 == 0 { "wikitext" } else { "ptb" };
                let r = handle.generate(task, ids[i % ids.len()].clone(), 16, EOS)?;
                lat.push(r.latency_s);
            }
            Ok(lat)
        }));
    }
    let mut all = Vec::new();
    for c in clients {
        all.extend(c.join().expect("client thread panicked")?);
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.handle().metrics()?;
    println!("\n== multitask serving ({size}, quantized path, scale-swap) ==");
    println!("requests: {} from 4 concurrent clients in {wall:.1}s", all.len());
    println!(
        "engine: {:.1} tok/s | p50 {:.3}s p99 {:.3}s | {} swaps, mean {:.2} ms",
        m.tokens_per_s(),
        m.p50_latency(),
        m.p99_latency(),
        m.swap_times_s.len(),
        m.mean_swap_s() * 1e3,
    );
    println!("decode steps {} for {} tokens (batching gain {:.1}x)",
        m.decode_steps, m.generated_tokens,
        m.generated_tokens as f64 / m.decode_steps.max(1) as f64);
    server.shutdown();
    Ok(())
}
