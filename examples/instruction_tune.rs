//! Instruction tuning + restoration (Section 4.3 in miniature).
//!
//! RTN 4-bit quantization visibly damages the base model's knowledge
//! (mmlu-sim accuracy drops); PEQA instruction-tuning on alpaca-sim —
//! updating ONLY the quantization scales — restores it, at 1/8 of the
//! fp32 model bytes. Also prints a few greedy generations so you can see
//! the instruction format being learned.
//!
//! Run: cargo run --release --example instruction_tune [-- --size n3]

use peqa::cli::Args;
use peqa::data;
use peqa::eval::{generate, mc_accuracy, EvalModel};
use peqa::pipeline::{self, Ctx};
use peqa::tokenizer::{BOS, EOS};

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    let size = args.get("size", "n3");
    let steps = args.get_usize("steps", 120)?;
    args.finish()?;
    let ctx = Ctx::new()?;

    println!("== models: base vs RTN vs RTN+PEQA(alpaca-sim) ==");
    let base = pipeline::instruct_tuned(&ctx, &size, "base", 256, steps)?;
    let rtn = pipeline::instruct_tuned(&ctx, &size, "rtn_b4", 256, steps)?;
    let peqa = pipeline::instruct_tuned(&ctx, &size, "peqa_b4_gc", 256, steps)?;

    let suite = data::mmlu_sim(&ctx.world, 3, 24);
    let art = format!("{size}_logits_b8");
    let mut avg = [0.0f64; 3];
    println!("\nmmlu-sim 5-shot accuracy (%):");
    println!("{:10} {:>8} {:>8} {:>8}", "domain", "base", "RTN", "PEQA");
    for task in &suite {
        let a0 = mc_accuracy(&ctx.rt, &art, &base, &ctx.tok, task, 5, 7)? * 100.0;
        let a1 =
            mc_accuracy(&ctx.rt, &art, &rtn.dequantize()?, &ctx.tok, task, 5, 7)? * 100.0;
        let a2 =
            mc_accuracy(&ctx.rt, &art, &peqa.dequantize()?, &ctx.tok, task, 5, 7)? * 100.0;
        println!("{:10} {a0:>8.1} {a1:>8.1} {a2:>8.1}", task.name);
        avg[0] += a0 / suite.len() as f64;
        avg[1] += a1 / suite.len() as f64;
        avg[2] += a2 / suite.len() as f64;
    }
    println!("{:10} {:>8.1} {:>8.1} {:>8.1}", "AVERAGE", avg[0], avg[1], avg[2]);

    println!("\nsample generations (PEQA-tuned, greedy):");
    let model = EvalModel::new(&ctx.rt, &art, &peqa.dequantize()?)?;
    for ins in data::ni_sim(&ctx.world, 4, 3) {
        let mut prompt = vec![BOS];
        prompt.extend(ctx.tok.encode(&ins.prompt));
        let out = generate(&model, &ctx.rt, &prompt, 14, EOS)?;
        println!("  {:60} -> {:?}", ins.prompt, ctx.tok.decode(&out)?);
    }

    println!(
        "\nrestoration: RTN dropped the average by {:.1} pts; PEQA recovered {:.1} pts \
         while keeping the 4-bit integer model.",
        avg[0] - avg[1],
        avg[2] - avg[1]
    );
    Ok(())
}
