//! Quickstart: the full PEQA lifecycle on the smallest model in ~30s.
//!
//!   1. pretrain (or load) an fp base model,
//!   2. quantize it to 4-bit (Eq. 1 RTN — the Pallas `prep` artifact),
//!   3. fine-tune ONLY the scales on wikitext-sim (Eq. 2),
//!   4. evaluate PPL: base vs RTN-quantized vs PEQA-tuned,
//!   5. pack to the sub-4-bit deployment file and extract the task adapter.
//!
//! Run: cargo run --release --example quickstart

use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let size = "n1";

    println!("== 1. base model ==");
    let base = pipeline::ensure_base(&ctx, size, pipeline::pretrain_steps())?;
    let (train_s, eval_s) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;
    let base_ppl = pipeline::ppl(&ctx, size, &base, &eval_s)?;
    println!("base ({} params): wikitext-sim ppl {base_ppl:.2}", base.n_params());

    println!("\n== 2. RTN 4-bit quantization (no tuning) ==");
    let rtn = pipeline::rtn_quantize(&base, 4, None)?;
    let rtn_ppl = pipeline::ppl(&ctx, size, &rtn, &eval_s)?;
    println!("RTN 4-bit: ppl {rtn_ppl:.2} (degraded by {:+.2})", rtn_ppl - base_ppl);

    println!("\n== 3. PEQA: fine-tune only the quantization scales ==");
    let cfg = pipeline::default_cfg("peqa_b4_gc", 120, 42);
    let (tuned, losses) = pipeline::finetune(&ctx, size, "peqa_b4_gc", &base, &train_s, &cfg)?;
    println!(
        "trained {} steps, loss {:.3} → {:.3}",
        losses.len(),
        losses.first().unwrap(),
        losses.last().unwrap()
    );
    let peqa_ppl = pipeline::ppl(&ctx, size, &tuned, &eval_s)?;
    println!("PEQA 4-bit: ppl {peqa_ppl:.2}");

    println!("\n== 4. deployment artifacts ==");
    let dir = std::env::temp_dir().join("peqa_quickstart");
    std::fs::create_dir_all(&dir)?;
    let packed = tuned.save_packed(&dir.join("model.packed"), 4)?;
    let adapter = tuned.extract_adapter(false);
    adapter.save(&dir.join("wikitext.adapter"))?;
    let adapter_bytes = std::fs::metadata(dir.join("wikitext.adapter"))?.len();
    println!(
        "packed 4-bit model: {}   (fp32 would be {})",
        peqa::util::human_bytes(packed),
        peqa::util::human_bytes(base.n_params() as u64 * 4),
    );
    println!(
        "task adapter (just the scales): {} — swapping it IS task switching",
        peqa::util::human_bytes(adapter_bytes)
    );

    println!("\nsummary: base {base_ppl:.2} | RTN {rtn_ppl:.2} | PEQA {peqa_ppl:.2}");
    assert!(peqa_ppl < rtn_ppl, "PEQA tuning must beat raw RTN");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
