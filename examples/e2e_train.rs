//! END-TO-END driver (DESIGN.md deliverable): proves all three layers
//! compose on a real workload.
//!
//! Pretrains the largest family model (n6, ~5M params — the biggest that
//! pretrains in minutes on this 1-core CPU testbed; see DESIGN.md
//! §Substitutions) for several hundred steps through the full
//! rust→PJRT→XLA(train_step HLO, with the Pallas kernels inside) path,
//! logging the loss curve, then runs the paper's headline experiment on
//! it: 4-bit RTN degradation vs PEQA restoration vs LoRA fp16.
//!
//! Run: cargo run --release --example e2e_train [-- --steps 400 --size n6]
//! Results land in results/e2e_loss.csv + stdout (recorded in EXPERIMENTS.md).

use peqa::cli::Args;
use peqa::config::TrainConfig;
use peqa::data::LmBatcher;
use peqa::model::Checkpoint;
use peqa::pipeline::{self, Ctx};
use peqa::train::{Trainer, Tuner};

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    let size = args.get("size", "n6");
    let steps = args.get_usize("steps", 400)?;
    let ft_steps = args.get_usize("ft-steps", 150)?;
    args.finish()?;

    let ctx = Ctx::new()?;
    let t0 = std::time::Instant::now();

    // ---- Phase 1: pretrain through the full stack, log the curve. ----
    println!("== e2e: pretraining {size} for {steps} steps ==");
    let art = format!("{size}_train_full");
    let meta = ctx.rt.meta(&art)?;
    let n_params = meta.model.as_ref().unwrap().n_params;
    println!("model: {n_params} params, artifact {art}");
    let metas: Vec<_> = meta.params_trainable.iter().collect();
    let init = Checkpoint::init_from_meta(&metas, 1234)?;
    let cfg = TrainConfig {
        steps,
        lr: TrainConfig::default_lr("full"),
        warmup_steps: steps / 20 + 1,
        log_every: 25,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&ctx.rt, &art, &init, cfg)?;
    let stream = ctx.stream("pretrain", pipeline::PRETRAIN_BYTES)?;
    let (b, t) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
    let mut batcher = LmBatcher::new(stream, b, t, 77);
    trainer.run(steps, || batcher.next_batch())?;
    let losses = trainer.losses().to_vec();
    let base = trainer.finish()?;
    let pretrain_s = t0.elapsed().as_secs_f64();
    let tokens_seen = steps * b * t;
    println!(
        "pretrained in {pretrain_s:.0}s ({:.0} tok/s): loss {:.3} → {:.3}",
        tokens_seen as f64 / pretrain_s,
        losses.first().unwrap(),
        losses.last().unwrap()
    );

    // Dump the loss curve.
    std::fs::create_dir_all(&ctx.paths.results)?;
    let mut csv = String::from("step,loss\n");
    for (i, l) in losses.iter().enumerate() {
        csv.push_str(&format!("{},{}\n", i + 1, l));
    }
    std::fs::write(ctx.paths.results.join("e2e_loss.csv"), &csv)?;
    println!("loss curve → results/e2e_loss.csv");
    assert!(
        losses.last().unwrap() + 0.5 < losses[..10.min(losses.len())].iter().sum::<f32>() / 10.0,
        "pretraining must reduce the loss substantially"
    );

    // ---- Phase 2: the headline PEQA experiment on the trained model. ----
    println!("\n== e2e: adapt to wikitext-sim ==");
    let (train_s, eval_s) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;
    let base_ppl = pipeline::ppl(&ctx, &size, &base, &eval_s)?;
    let rtn = pipeline::rtn_quantize(&base, 4, None)?;
    let rtn_ppl = pipeline::ppl(&ctx, &size, &rtn, &eval_s)?;

    let cfg = pipeline::default_cfg("peqa_b4_gc", ft_steps, 9);
    let (peqa_ck, _) = pipeline::finetune(&ctx, &size, "peqa_b4_gc", &base, &train_s, &cfg)?;
    let peqa_ppl = pipeline::ppl(&ctx, &size, &peqa_ck, &eval_s)?;

    let cfg = pipeline::default_cfg("lora_qv4", ft_steps, 9);
    let (lora_ck, _) = pipeline::finetune(&ctx, &size, "lora_qv4", &base, &train_s, &cfg)?;
    let lora_ppl = pipeline::lora_ppl(&ctx, &size, "lora_qv4", &lora_ck, &eval_s)?;

    let dir = std::env::temp_dir().join("peqa_e2e");
    std::fs::create_dir_all(&dir)?;
    let packed = peqa_ck.save_packed(&dir.join("m.packed"), 4)?;
    println!("\n== e2e headline ({size}, wikitext-sim) ==");
    println!("base fp32                : ppl {base_ppl:.2}  ({} B)", base.n_params() * 4);
    println!("RTN 4-bit (no tuning)    : ppl {rtn_ppl:.2}");
    println!("PEQA 4-bit (scales only) : ppl {peqa_ppl:.2}  ({packed} B packed)");
    println!("LoRA fp32 (QV4)          : ppl {lora_ppl:.2}");
    println!("total wall time {:.0}s", t0.elapsed().as_secs_f64());
    assert!(peqa_ppl < rtn_ppl, "PEQA must restore the RTN degradation");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
